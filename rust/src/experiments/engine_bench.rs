//! E9 — engine-core benchmark: the typed-event calendar engine against
//! the boxed-closure baseline it replaced, and the leaf-partitioned
//! parallel executive against the sequential typed engine.
//!
//! For every node count, one paper-sized all-reduce runs to completion
//! on the unified engine under the three scale-relevant plan families —
//! the NIC ring, the planner's hierarchical plan, and NetReduce-style
//! in-switch reduction — on the planner study's 4:1-tapered leaf–spine
//! fabric (racks of 8, contiguous placement).  Every point records
//! events executed, events/second, peak queue depth and wall-clock; at
//! the baselined node counts the same scenario is re-run on
//! `EngineKind::BoxedBaseline` (the PR-3 representation: one
//! `Box<dyn FnOnce>` per event on a `BinaryHeap`, compiled only under
//! the `testing` feature) so the speedup is measured, not estimated.
//! NIC-ring points additionally re-run on `EngineKind::Parallel` at
//! every configured thread count; those runs are uncapped, so the
//! parallel executive's virtual time is checked against the typed
//! engine's to [`VIRTUAL_TIME_TOL`].
//!
//! A second, ring-only sweep takes the engine to 16k–64k nodes.  Full
//! completion there costs 10^10+ events, so every scaling run burns the
//! same bounded event budget ([`EngineBenchConfig::max_events`]) and
//! reports throughput over that budget — the honest way to compare
//! engines at node counts nothing finishes at.  The 4-thread parallel
//! run targets [`PARALLEL_SPEEDUP_GATE`]x the single-thread events/sec
//! on the [`PARALLEL_GATE_NODES`]-node ring; missing the target warns,
//! and only dropping below [`PARALLEL_SPEEDUP_FLOOR`]x fails the run.
//!
//! NIC-ring points also re-run under the checked executive
//! (`EngineKind::Checked` — the invariant auditor of
//! docs/INVARIANTS.md) at every configured thread count: each audited
//! run must report zero violations (a violation fails the bench), and
//! its wall-clock overhead over the matching unchecked run is recorded
//! against [`CHECKED_OVERHEAD_TOL`] (warn-only, like the parallel
//! scaling target: wall-clock ratios are noisy on shared runners).
//!
//! `smartnic engine-bench` prints the tables and writes
//! `BENCH_engine.json` (schema documented in `docs/BENCHMARKS.md`,
//! pinned by `rust/tests/bench_schema.rs`).  The run fails (nonzero
//! exit) if any gate with data fails.

use crate::analytic::model::SystemKind;
use crate::cluster::{
    run_scenario_capped, run_scenario_on, ClusterSpec, CollectiveAlgo, EngineKind, JobSpec,
    PartitionStats, ScenarioOutput, Topology,
};
use crate::experiments::planner::{leaf_shape, planner_system};
use crate::sysconfig::Workload;
use crate::util::json::Json;
use crate::util::stats::rel_err;
use crate::util::table::{fnum, Table};
use std::time::Instant;

/// Plan families benchmarked at every node count, in row order.
pub const ALGOS: [(&str, CollectiveAlgo); 3] = [
    ("nic-ring", CollectiveAlgo::NicRing),
    ("hierarchical", CollectiveAlgo::NicHierarchical),
    ("in-switch", CollectiveAlgo::SwitchReduce),
];

/// Wall-clock speedup the typed engine must reach over the boxed
/// baseline on the NIC ring at [`GATE_NODES`] nodes.
pub const SPEEDUP_GATE: f64 = 5.0;

/// Node count the speedup gate is pinned at (the PR-2 sweep's largest
/// point, where the boxed engine scheduled tens of millions of
/// closures).
pub const GATE_NODES: usize = 512;

/// Engine backends must agree on every virtual-time result to this
/// relative tolerance.  Typed vs boxed execute the identical event
/// order, so the observed deviation is exactly zero; the parallel
/// executive reorders only exact ties, so its deviation is float dust.
pub const VIRTUAL_TIME_TOL: f64 = 1e-9;

/// Events/sec ratio the [`PARALLEL_GATE_THREADS`]-thread parallel run
/// targets over the single-thread parallel run on the
/// [`PARALLEL_GATE_NODES`]-node ring scaling point.  Missing the target
/// is a warning, not a process failure: wall-clock speedup on shared CI
/// runners is contention-noisy, so the hard exit-code gate sits at
/// [`PARALLEL_SPEEDUP_FLOOR`] and the target is tracked in
/// `BENCH_engine.json` (`gates.parallel_scaling_pass`).
pub const PARALLEL_SPEEDUP_GATE: f64 = 2.0;

/// Hard floor for the parallel scaling gate: below this the run exits
/// nonzero even on a noisy runner, because a 4-thread drain slower than
/// ~1.2x single-thread signals a real regression (lost parallelism, a
/// serialization bug), not scheduler jitter.
pub const PARALLEL_SPEEDUP_FLOOR: f64 = 1.2;

/// Wall-clock overhead budget of the checked executive over the
/// matching unchecked engine (0.10 = 10%).  Tracked in
/// `BENCH_engine.json` (`gates.checked_overhead_pass`) and surfaced as
/// a warning when exceeded; audit *violations* fail the bench outright.
pub const CHECKED_OVERHEAD_TOL: f64 = 0.10;

/// Scaling-sweep node count the parallel speedup gate is pinned at.
pub const PARALLEL_GATE_NODES: usize = 16384;

/// Worker-thread count the parallel speedup gate is pinned at.
pub const PARALLEL_GATE_THREADS: usize = 4;

/// Sweep parameters.
#[derive(Clone, Debug)]
pub struct EngineBenchConfig {
    /// node counts for the typed-engine sweep (even, >= 4)
    pub nodes: Vec<usize>,
    /// node counts additionally re-run on the boxed-closure baseline
    pub baseline_nodes: Vec<usize>,
    /// worker-thread counts for the parallel executive rows
    pub threads: Vec<usize>,
    /// ring-only node counts for the event-budget-capped scaling sweep
    pub scaling_nodes: Vec<usize>,
    /// event budget every scaling run burns before stopping
    pub max_events: u64,
    /// leaf uplink oversubscription factor
    pub oversubscription: f64,
    /// gradient width: hidden² elements per all-reduce
    pub hidden: usize,
}

impl Default for EngineBenchConfig {
    fn default() -> Self {
        Self {
            nodes: vec![128, 512, 2048],
            baseline_nodes: vec![128, 512],
            threads: vec![1, 2, 4],
            scaling_nodes: vec![4096, 16384, 65536],
            max_events: 2_000_000,
            oversubscription: 4.0,
            hidden: 2048,
        }
    }
}

/// One parallel-executive re-run of a typed sweep point.
#[derive(Clone, Debug)]
pub struct ParallelRow {
    pub threads: usize,
    pub wall_s: f64,
    pub events_per_sec: f64,
    /// relative virtual-time deviation parallel vs typed
    pub virtual_err: f64,
    /// events on the busiest leaf partition over the per-leaf mean
    pub imbalance: Option<f64>,
}

/// One checked-executive (audited) re-run of a NIC-ring point.
#[derive(Clone, Debug)]
pub struct CheckedRow {
    /// audited worker threads (0 = sequential audited run)
    pub threads: usize,
    pub wall_s: f64,
    pub events_per_sec: f64,
    /// relative virtual-time deviation checked vs typed
    pub virtual_err: f64,
    /// checked wall-clock over the matching unchecked run, minus one
    /// (0.07 = 7% audit overhead)
    pub overhead: f64,
    /// audit violations reported (must be zero on a healthy engine)
    pub violations: usize,
}

/// One (node count, plan family) cell of the benchmark.
#[derive(Clone, Debug)]
pub struct EnginePoint {
    pub nodes: usize,
    pub algo: &'static str,
    /// virtual makespan of the scenario (seconds of simulated time)
    pub virtual_s: f64,
    /// events executed by the typed engine
    pub events: u64,
    /// high-water mark of the typed engine's pending-event count
    pub peak_queue: usize,
    /// typed-engine wall-clock (seconds)
    pub wall_s: f64,
    /// typed-engine throughput
    pub events_per_sec: f64,
    /// boxed-closure baseline wall-clock (None when not baselined)
    pub baseline_wall_s: Option<f64>,
    pub baseline_events_per_sec: Option<f64>,
    /// baseline wall-clock over typed wall-clock
    pub speedup: Option<f64>,
    /// relative virtual-time deviation typed vs boxed
    pub virtual_err: Option<f64>,
    /// parallel-executive re-runs (NIC-ring points only)
    pub parallel: Vec<ParallelRow>,
    /// checked-executive (audited) re-runs (NIC-ring points only)
    pub checked: Vec<CheckedRow>,
}

/// One row of the event-budget-capped ring scaling sweep.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub nodes: usize,
    /// parallel worker threads; 0 marks the sequential typed reference
    pub threads: usize,
    /// virtual time reached when the event budget ran out
    pub virtual_s: f64,
    pub events: u64,
    pub wall_s: f64,
    pub events_per_sec: f64,
    /// events on the busiest leaf partition over the per-leaf mean
    pub imbalance: Option<f64>,
}

/// The scenario a point runs: one `hidden`²-element all-reduce on the
/// planner study's provisioned leaf–spine fabric, contiguous placement.
fn bench_spec(n: usize, algo: CollectiveAlgo, cfg: &EngineBenchConfig) -> ClusterSpec {
    assert!(n >= 4 && n % 2 == 0, "engine bench needs even node counts >= 4, got {n}");
    let (leaves, m) = leaf_shape(n);
    let sys = planner_system(leaves, m);
    let topo = Topology::leaf_spine(leaves, m, cfg.oversubscription);
    let w = Workload {
        layers: 1,
        hidden: cfg.hidden,
        batch_per_node: 64,
    };
    ClusterSpec::new(sys, n).with_topology(topo).with_job(
        JobSpec::new("bench", SystemKind::SmartNic { bfp: false }, w, topo.contiguous_ranks(n))
            .with_layer_algos(vec![algo]),
    )
}

fn timed_run(spec: &ClusterSpec, engine: EngineKind) -> (ScenarioOutput, f64) {
    let t0 = Instant::now();
    let out = run_scenario_on(spec, engine);
    (out, t0.elapsed().as_secs_f64())
}

/// Busiest leaf partition's event count over the per-leaf mean.  `None`
/// for sequential runs (no partitions) or degenerate fabrics.
fn imbalance(parts: &[PartitionStats]) -> Option<f64> {
    // entry 0 is the coordinator; leaves start at 1
    let leaves = parts.get(1..)?;
    let total: u64 = leaves.iter().map(|p| p.events).sum();
    if leaves.is_empty() || total == 0 {
        return None;
    }
    let mean = total as f64 / leaves.len() as f64;
    let max = leaves.iter().map(|p| p.events).max().unwrap_or(0) as f64;
    Some(max / mean)
}

/// The boxed-closure baseline exists only when the `testing` feature
/// compiles it; production builds of the bench report no baseline rows
/// rather than carrying the dead representation.
#[cfg(any(test, feature = "testing"))]
fn baseline_run(spec: &ClusterSpec) -> Option<(ScenarioOutput, f64)> {
    Some(timed_run(spec, EngineKind::BoxedBaseline))
}

#[cfg(not(any(test, feature = "testing")))]
fn baseline_run(_spec: &ClusterSpec) -> Option<(ScenarioOutput, f64)> {
    None
}

/// Run the full-completion benchmark sweep.
pub fn run(cfg: &EngineBenchConfig) -> Vec<EnginePoint> {
    let mut out = Vec::new();
    for &n in &cfg.nodes {
        for (name, algo) in ALGOS {
            let spec = bench_spec(n, algo, cfg);
            let (typed, wall) = timed_run(&spec, EngineKind::Typed);
            let mut point = EnginePoint {
                nodes: n,
                algo: name,
                virtual_s: typed.makespan,
                events: typed.events,
                peak_queue: typed.peak_queue_depth,
                wall_s: wall,
                events_per_sec: typed.events as f64 / wall.max(1e-12),
                baseline_wall_s: None,
                baseline_events_per_sec: None,
                speedup: None,
                virtual_err: None,
                parallel: Vec::new(),
                checked: Vec::new(),
            };
            if cfg.baseline_nodes.contains(&n) {
                if let Some((boxed, boxed_wall)) = baseline_run(&spec) {
                    assert_eq!(
                        boxed.events, typed.events,
                        "engines diverged in event count at n={n} {name}"
                    );
                    point.baseline_wall_s = Some(boxed_wall);
                    point.baseline_events_per_sec =
                        Some(boxed.events as f64 / boxed_wall.max(1e-12));
                    point.speedup = Some(boxed_wall / wall.max(1e-12));
                    point.virtual_err = Some(rel_err(boxed.makespan, typed.makespan));
                }
            }
            if name == "nic-ring" {
                for &t in &cfg.threads {
                    let (par, par_wall) = timed_run(&spec, EngineKind::Parallel { threads: t });
                    assert_eq!(
                        par.events, typed.events,
                        "parallel executive diverged in event count at n={n} threads={t}"
                    );
                    point.parallel.push(ParallelRow {
                        threads: t,
                        wall_s: par_wall,
                        events_per_sec: par.events as f64 / par_wall.max(1e-12),
                        virtual_err: rel_err(par.makespan, typed.makespan),
                        imbalance: imbalance(&par.partitions),
                    });
                    let (chk, chk_wall) = timed_run(&spec, EngineKind::Checked { threads: t });
                    assert_eq!(
                        chk.events, typed.events,
                        "checked executive diverged in event count at n={n} threads={t}"
                    );
                    let violations =
                        chk.audit.as_ref().map_or(0, |r| r.total()) as usize;
                    point.checked.push(CheckedRow {
                        threads: t,
                        wall_s: chk_wall,
                        events_per_sec: chk.events as f64 / chk_wall.max(1e-12),
                        virtual_err: rel_err(chk.makespan, typed.makespan),
                        overhead: chk_wall / par_wall.max(1e-12) - 1.0,
                        violations,
                    });
                }
            }
            out.push(point);
        }
    }
    out
}

/// Run the event-budget-capped ring scaling sweep: per node count one
/// typed reference plus one parallel run per configured thread count,
/// each burning [`EngineBenchConfig::max_events`].
pub fn run_scaling(cfg: &EngineBenchConfig) -> Vec<ScalingPoint> {
    let mut out = Vec::new();
    for &n in &cfg.scaling_nodes {
        let spec = bench_spec(n, CollectiveAlgo::NicRing, cfg);
        let t0 = Instant::now();
        let typed = run_scenario_capped(&spec, EngineKind::Typed, cfg.max_events);
        let wall = t0.elapsed().as_secs_f64();
        out.push(ScalingPoint {
            nodes: n,
            threads: 0,
            virtual_s: typed.virtual_s,
            events: typed.events,
            wall_s: wall,
            events_per_sec: typed.events as f64 / wall.max(1e-12),
            imbalance: None,
        });
        for &t in &cfg.threads {
            let t0 = Instant::now();
            let engine = EngineKind::Parallel { threads: t };
            let par = run_scenario_capped(&spec, engine, cfg.max_events);
            let wall = t0.elapsed().as_secs_f64();
            out.push(ScalingPoint {
                nodes: n,
                threads: t,
                virtual_s: par.virtual_s,
                events: par.events,
                wall_s: wall,
                events_per_sec: par.events as f64 / wall.max(1e-12),
                imbalance: imbalance(&par.partitions),
            });
        }
    }
    out
}

/// The gate measurement: typed-vs-boxed wall-clock speedup on the NIC
/// ring at [`GATE_NODES`] nodes.  `None` when the sweep holds no
/// baselined ring run there — the gate then has nothing to say and must
/// not report a vacuous PASS.
pub fn gate_speedup(points: &[EnginePoint]) -> Option<f64> {
    points
        .iter()
        .find(|p| p.nodes == GATE_NODES && p.algo == "nic-ring")
        .and_then(|p| p.speedup)
}

/// Worst typed-vs-boxed virtual-time deviation across baselined points.
pub fn worst_virtual_err(points: &[EnginePoint]) -> Option<f64> {
    points
        .iter()
        .filter_map(|p| p.virtual_err)
        .fold(None, |acc: Option<f64>, e| Some(acc.map_or(e, |a| a.max(e))))
}

/// Worst parallel-vs-typed virtual-time deviation across the uncapped
/// parallel rows of the full-completion sweep.
pub fn worst_parallel_virtual_err(points: &[EnginePoint]) -> Option<f64> {
    points
        .iter()
        .flat_map(|p| p.parallel.iter().map(|r| r.virtual_err))
        .fold(None, |acc: Option<f64>, e| Some(acc.map_or(e, |a| a.max(e))))
}

/// Worst checked-vs-typed virtual-time deviation across the audited
/// re-runs of the full-completion sweep.
pub fn worst_checked_virtual_err(points: &[EnginePoint]) -> Option<f64> {
    points
        .iter()
        .flat_map(|p| p.checked.iter().map(|r| r.virtual_err))
        .fold(None, |acc: Option<f64>, e| Some(acc.map_or(e, |a| a.max(e))))
}

/// Largest wall-clock overhead of a checked run over its matching
/// unchecked run.  `None` when no audited rows exist — no vacuous PASS.
pub fn worst_checked_overhead(points: &[EnginePoint]) -> Option<f64> {
    points
        .iter()
        .flat_map(|p| p.checked.iter().map(|r| r.overhead))
        .fold(None, |acc: Option<f64>, e| Some(acc.map_or(e, |a| a.max(e))))
}

/// Total audit violations across every checked run.  `None` when no
/// audited rows exist; any nonzero total fails the bench.
pub fn checked_violation_total(points: &[EnginePoint]) -> Option<usize> {
    let rows: Vec<usize> =
        points.iter().flat_map(|p| p.checked.iter().map(|r| r.violations)).collect();
    if rows.is_empty() {
        None
    } else {
        Some(rows.iter().sum())
    }
}

/// The parallel scaling gate: events/sec of the
/// [`PARALLEL_GATE_THREADS`]-thread run over the 1-thread run on the
/// [`PARALLEL_GATE_NODES`]-node ring scaling point.  `None` when the
/// sweep holds no such pair — no vacuous PASS.
pub fn parallel_gate_speedup(scaling: &[ScalingPoint]) -> Option<f64> {
    let eps = |t: usize| {
        scaling
            .iter()
            .find(|p| p.nodes == PARALLEL_GATE_NODES && p.threads == t)
            .map(|p| p.events_per_sec)
    };
    match (eps(PARALLEL_GATE_THREADS), eps(1)) {
        (Some(multi), Some(single)) if single > 0.0 => Some(multi / single),
        _ => None,
    }
}

/// Largest node count the full-completion sweep completed.
pub fn max_nodes_completed(points: &[EnginePoint]) -> usize {
    points.iter().map(|p| p.nodes).max().unwrap_or(0)
}

/// Largest node count the capped scaling sweep produced a measurement
/// for (every row executed at least one event).
pub fn scaling_max_nodes(scaling: &[ScalingPoint]) -> usize {
    scaling.iter().filter(|p| p.events > 0).map(|p| p.nodes).max().unwrap_or(0)
}

pub fn print(points: &[EnginePoint], scaling: &[ScalingPoint], cfg: &EngineBenchConfig) {
    let mut t = Table::new(&[
        "nodes",
        "algo",
        "events",
        "peak queue",
        "typed (s)",
        "Mev/s",
        "boxed (s)",
        "speedup",
    ])
    .with_title(&format!(
        "engine bench — typed arena vs boxed closures, hidden={} on {}:1 leaf-spine",
        cfg.hidden, cfg.oversubscription
    ));
    for p in points {
        t.row(&[
            p.nodes.to_string(),
            p.algo.to_string(),
            p.events.to_string(),
            p.peak_queue.to_string(),
            fnum(p.wall_s, 3),
            fnum(p.events_per_sec / 1e6, 2),
            p.baseline_wall_s.map_or("-".to_string(), |w| fnum(w, 3)),
            p.speedup.map_or("-".to_string(), |s| format!("x{}", fnum(s, 2))),
        ]);
    }
    t.print();
    if points.iter().any(|p| !p.parallel.is_empty()) {
        let mut t = Table::new(&["nodes", "threads", "wall (s)", "Mev/s", "virtual err", "imbal"])
            .with_title("parallel executive — uncapped NIC-ring re-runs vs typed");
        for p in points {
            for r in &p.parallel {
                t.row(&[
                    p.nodes.to_string(),
                    r.threads.to_string(),
                    fnum(r.wall_s, 3),
                    fnum(r.events_per_sec / 1e6, 2),
                    format!("{:.1e}", r.virtual_err),
                    r.imbalance.map_or("-".to_string(), |i| fnum(i, 2)),
                ]);
            }
        }
        t.print();
    }
    if points.iter().any(|p| !p.checked.is_empty()) {
        let mut t =
            Table::new(&["nodes", "threads", "wall (s)", "Mev/s", "virtual err", "overhead", "viol"])
                .with_title("checked executive — audited NIC-ring re-runs vs unchecked");
        for p in points {
            for r in &p.checked {
                t.row(&[
                    p.nodes.to_string(),
                    r.threads.to_string(),
                    fnum(r.wall_s, 3),
                    fnum(r.events_per_sec / 1e6, 2),
                    format!("{:.1e}", r.virtual_err),
                    format!("{:+.1}%", r.overhead * 100.0),
                    r.violations.to_string(),
                ]);
            }
        }
        t.print();
    }
    if !scaling.is_empty() {
        let mut t =
            Table::new(&["nodes", "engine", "events", "virtual (s)", "wall (s)", "Mev/s", "imbal"])
                .with_title(&format!(
                    "ring scaling sweep — {} events per run, typed reference vs parallel",
                    cfg.max_events
                ));
        for p in scaling {
            let engine = if p.threads == 0 {
                "typed".to_string()
            } else {
                format!("par x{}", p.threads)
            };
            t.row(&[
                p.nodes.to_string(),
                engine,
                p.events.to_string(),
                fnum(p.virtual_s, 6),
                fnum(p.wall_s, 3),
                fnum(p.events_per_sec / 1e6, 2),
                p.imbalance.map_or("-".to_string(), |i| fnum(i, 2)),
            ]);
        }
        t.print();
    }
    match gate_speedup(points) {
        Some(s) => println!(
            "typed vs boxed on the {GATE_NODES}-node NIC ring: x{:.2} (gate x{SPEEDUP_GATE}) — {}",
            s,
            if s >= SPEEDUP_GATE { "PASS" } else { "FAIL" }
        ),
        None => println!(
            "speedup gate: not validated (no baselined {GATE_NODES}-node NIC ring in the sweep)"
        ),
    }
    match worst_virtual_err(points) {
        Some(e) => println!(
            "virtual-time parity typed vs boxed: worst {:.2e} (tol {VIRTUAL_TIME_TOL:.0e}) — {}",
            e,
            if e <= VIRTUAL_TIME_TOL { "PASS" } else { "FAIL" }
        ),
        None => println!("virtual-time parity: not validated (no baselined points)"),
    }
    match worst_parallel_virtual_err(points) {
        Some(e) => println!(
            "virtual-time parity parallel vs typed: worst {:.2e} (tol {VIRTUAL_TIME_TOL:.0e}) — {}",
            e,
            if e <= VIRTUAL_TIME_TOL { "PASS" } else { "FAIL" }
        ),
        None => println!("parallel parity: not validated (no parallel rows)"),
    }
    match (checked_violation_total(points), worst_checked_overhead(points)) {
        (Some(v), Some(o)) => println!(
            "checked executive: {v} violation(s) — {}; worst overhead {:+.1}% \
             (budget {:.0}%) — {}",
            if v == 0 { "PASS" } else { "FAIL" },
            o * 100.0,
            CHECKED_OVERHEAD_TOL * 100.0,
            if o <= CHECKED_OVERHEAD_TOL { "PASS" } else { "WARN (over budget)" }
        ),
        _ => println!("checked executive: not validated (no audited rows)"),
    }
    match parallel_gate_speedup(scaling) {
        Some(s) => println!(
            "parallel x{PARALLEL_GATE_THREADS} vs x1 on the {PARALLEL_GATE_NODES}-node ring: \
             x{:.2} (target x{PARALLEL_SPEEDUP_GATE}, hard floor x{PARALLEL_SPEEDUP_FLOOR}) — {}",
            s,
            if s >= PARALLEL_SPEEDUP_GATE {
                "PASS"
            } else if s >= PARALLEL_SPEEDUP_FLOOR {
                "WARN (below target, above floor)"
            } else {
                "FAIL"
            }
        ),
        None => println!(
            "parallel scaling gate: not validated (no {PARALLEL_GATE_NODES}-node scaling pair)"
        ),
    }
    println!("largest completed sweep: {} nodes", max_nodes_completed(points));
    if !scaling.is_empty() {
        println!("largest capped scaling point: {} nodes", scaling_max_nodes(scaling));
    }
}

/// Serialize the benchmark to the `BENCH_engine.json` schema
/// (documented in `docs/BENCHMARKS.md`).
pub fn to_json(cfg: &EngineBenchConfig, points: &[EnginePoint], scaling: &[ScalingPoint]) -> Json {
    Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("hidden", Json::Num(cfg.hidden as f64)),
                ("oversubscription", Json::Num(cfg.oversubscription)),
                ("speedup_gate", Json::Num(SPEEDUP_GATE)),
                ("gate_nodes", Json::Num(GATE_NODES as f64)),
                ("virtual_time_tol", Json::Num(VIRTUAL_TIME_TOL)),
                (
                    "threads",
                    Json::Arr(cfg.threads.iter().map(|&t| Json::Num(t as f64)).collect()),
                ),
                (
                    "scaling_nodes",
                    Json::Arr(cfg.scaling_nodes.iter().map(|&n| Json::Num(n as f64)).collect()),
                ),
                ("max_events", Json::Num(cfg.max_events as f64)),
                ("parallel_speedup_gate", Json::Num(PARALLEL_SPEEDUP_GATE)),
                ("parallel_speedup_floor", Json::Num(PARALLEL_SPEEDUP_FLOOR)),
                ("parallel_gate_nodes", Json::Num(PARALLEL_GATE_NODES as f64)),
                ("parallel_gate_threads", Json::Num(PARALLEL_GATE_THREADS as f64)),
                ("checked_overhead_tol", Json::Num(CHECKED_OVERHEAD_TOL)),
            ]),
        ),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        let baseline = match (p.baseline_wall_s, p.baseline_events_per_sec) {
                            (Some(wall), Some(eps)) => Json::obj(vec![
                                ("wall_s", Json::Num(wall)),
                                ("events_per_sec", Json::Num(eps)),
                                ("speedup", Json::Num(p.speedup.unwrap_or(0.0))),
                                ("virtual_err", Json::Num(p.virtual_err.unwrap_or(0.0))),
                            ]),
                            _ => Json::Null,
                        };
                        let parallel = Json::Arr(
                            p.parallel
                                .iter()
                                .map(|r| {
                                    Json::obj(vec![
                                        ("threads", Json::Num(r.threads as f64)),
                                        ("wall_s", Json::Num(r.wall_s)),
                                        ("events_per_sec", Json::Num(r.events_per_sec)),
                                        ("virtual_err", Json::Num(r.virtual_err)),
                                        ("imbalance", r.imbalance.map_or(Json::Null, Json::Num)),
                                    ])
                                })
                                .collect(),
                        );
                        let checked = Json::Arr(
                            p.checked
                                .iter()
                                .map(|r| {
                                    Json::obj(vec![
                                        ("threads", Json::Num(r.threads as f64)),
                                        ("wall_s", Json::Num(r.wall_s)),
                                        ("events_per_sec", Json::Num(r.events_per_sec)),
                                        ("virtual_err", Json::Num(r.virtual_err)),
                                        ("overhead", Json::Num(r.overhead)),
                                        ("violations", Json::Num(r.violations as f64)),
                                    ])
                                })
                                .collect(),
                        );
                        Json::obj(vec![
                            ("nodes", Json::Num(p.nodes as f64)),
                            ("algo", Json::Str(p.algo.to_string())),
                            ("virtual_s", Json::Num(p.virtual_s)),
                            ("events", Json::Num(p.events as f64)),
                            ("peak_queue_depth", Json::Num(p.peak_queue as f64)),
                            ("wall_s", Json::Num(p.wall_s)),
                            ("events_per_sec", Json::Num(p.events_per_sec)),
                            ("baseline", baseline),
                            ("parallel", parallel),
                            ("checked", checked),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "scaling",
            Json::Arr(
                scaling
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("nodes", Json::Num(p.nodes as f64)),
                            ("threads", Json::Num(p.threads as f64)),
                            ("virtual_s", Json::Num(p.virtual_s)),
                            ("events", Json::Num(p.events as f64)),
                            ("wall_s", Json::Num(p.wall_s)),
                            ("events_per_sec", Json::Num(p.events_per_sec)),
                            ("imbalance", p.imbalance.map_or(Json::Null, Json::Num)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "gates",
            Json::obj(vec![
                (
                    "ring_gate_speedup",
                    match gate_speedup(points) {
                        Some(s) => Json::Num(s),
                        None => Json::Null,
                    },
                ),
                (
                    "speedup_pass",
                    match gate_speedup(points) {
                        Some(s) => Json::Bool(s >= SPEEDUP_GATE),
                        None => Json::Null,
                    },
                ),
                (
                    "worst_virtual_err",
                    match worst_virtual_err(points) {
                        Some(e) => Json::Num(e),
                        None => Json::Null,
                    },
                ),
                (
                    "parallel_worst_virtual_err",
                    match worst_parallel_virtual_err(points) {
                        Some(e) => Json::Num(e),
                        None => Json::Null,
                    },
                ),
                (
                    "checked_worst_virtual_err",
                    match worst_checked_virtual_err(points) {
                        Some(e) => Json::Num(e),
                        None => Json::Null,
                    },
                ),
                (
                    "checked_worst_overhead",
                    match worst_checked_overhead(points) {
                        Some(o) => Json::Num(o),
                        None => Json::Null,
                    },
                ),
                (
                    "checked_overhead_pass",
                    match worst_checked_overhead(points) {
                        Some(o) => Json::Bool(o <= CHECKED_OVERHEAD_TOL),
                        None => Json::Null,
                    },
                ),
                (
                    "checked_violations",
                    match checked_violation_total(points) {
                        Some(v) => Json::Num(v as f64),
                        None => Json::Null,
                    },
                ),
                (
                    "parallel_scaling_speedup",
                    match parallel_gate_speedup(scaling) {
                        Some(s) => Json::Num(s),
                        None => Json::Null,
                    },
                ),
                (
                    "parallel_scaling_pass",
                    match parallel_gate_speedup(scaling) {
                        Some(s) => Json::Bool(s >= PARALLEL_SPEEDUP_GATE),
                        None => Json::Null,
                    },
                ),
                (
                    "parallel_scaling_floor_pass",
                    match parallel_gate_speedup(scaling) {
                        Some(s) => Json::Bool(s >= PARALLEL_SPEEDUP_FLOOR),
                        None => Json::Null,
                    },
                ),
                ("max_nodes_completed", Json::Num(max_nodes_completed(points) as f64)),
                ("scaling_max_nodes_completed", Json::Num(scaling_max_nodes(scaling) as f64)),
            ]),
        ),
    ])
}

/// Write the benchmark to `path` (repo convention: `BENCH_engine.json`,
/// uploaded as a CI artifact).
pub fn write_bench(
    path: &str,
    cfg: &EngineBenchConfig,
    points: &[EnginePoint],
    scaling: &[ScalingPoint],
) -> std::io::Result<()> {
    std::fs::write(path, to_json(cfg, points, scaling).to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> EngineBenchConfig {
        EngineBenchConfig {
            nodes: vec![8],
            baseline_nodes: vec![8],
            threads: vec![1, 2],
            scaling_nodes: vec![],
            max_events: 5_000,
            oversubscription: 4.0,
            hidden: 128,
        }
    }

    #[test]
    fn tiny_sweep_produces_all_plan_families() {
        let points = run(&tiny_cfg());
        assert_eq!(points.len(), ALGOS.len());
        for p in &points {
            assert!(p.events > 0, "{}: no events", p.algo);
            assert!(p.virtual_s > 0.0 && p.virtual_s.is_finite());
            assert!(p.peak_queue > 0);
            assert!(p.speedup.is_some(), "{}: baseline missing", p.algo);
        }
    }

    #[test]
    fn engines_agree_on_virtual_time() {
        let points = run(&tiny_cfg());
        let worst = worst_virtual_err(&points).expect("baselined points exist");
        assert!(worst <= VIRTUAL_TIME_TOL, "virtual-time drift {worst}");
    }

    #[test]
    fn parallel_rows_cover_the_ring_and_agree_with_typed() {
        let cfg = tiny_cfg();
        let points = run(&cfg);
        for p in &points {
            if p.algo == "nic-ring" {
                assert_eq!(p.parallel.len(), cfg.threads.len());
            } else {
                assert!(p.parallel.is_empty(), "{}: unexpected parallel rows", p.algo);
            }
        }
        let worst = worst_parallel_virtual_err(&points).expect("parallel rows exist");
        assert!(worst <= VIRTUAL_TIME_TOL, "parallel virtual-time drift {worst}");
    }

    #[test]
    fn checked_rows_are_clean_and_record_overhead() {
        let cfg = tiny_cfg();
        let points = run(&cfg);
        for p in &points {
            if p.algo == "nic-ring" {
                assert_eq!(p.checked.len(), cfg.threads.len());
                for r in &p.checked {
                    assert!(r.overhead.is_finite(), "overhead must be measured");
                }
            } else {
                assert!(p.checked.is_empty(), "{}: unexpected checked rows", p.algo);
            }
        }
        assert_eq!(checked_violation_total(&points), Some(0), "audited runs must be clean");
        assert!(worst_checked_overhead(&points).is_some(), "overhead must be recorded");
        let worst = worst_checked_virtual_err(&points).expect("checked rows exist");
        assert!(worst <= VIRTUAL_TIME_TOL, "checked virtual-time drift {worst}");
    }

    #[test]
    fn capped_scaling_sweep_reports_every_engine() {
        let cfg = EngineBenchConfig {
            scaling_nodes: vec![8],
            max_events: 500,
            ..tiny_cfg()
        };
        let scaling = run_scaling(&cfg);
        // typed reference + one row per thread count
        assert_eq!(scaling.len(), 1 + cfg.threads.len());
        assert_eq!(scaling[0].threads, 0);
        assert!(scaling[0].events <= cfg.max_events, "typed cap is strict");
        for p in &scaling {
            assert!(p.events > 0);
            assert!(p.virtual_s > 0.0 && p.virtual_s.is_finite());
        }
        assert_eq!(scaling_max_nodes(&scaling), 8);
        // an 8-node sweep cannot claim the 16384-node gate
        assert!(parallel_gate_speedup(&scaling).is_none());
    }

    #[test]
    fn gate_is_not_vacuous_without_the_pinned_point() {
        let points = run(&tiny_cfg());
        assert!(gate_speedup(&points).is_none(), "8-node sweep cannot claim the 512-node gate");
        assert_eq!(max_nodes_completed(&points), 8);
    }
}
