//! E9 — engine-core benchmark: the typed-event calendar engine against
//! the boxed-closure baseline it replaced.
//!
//! For every node count, one paper-sized all-reduce runs to completion
//! on the unified engine under the three scale-relevant plan families —
//! the NIC ring, the planner's hierarchical plan, and NetReduce-style
//! in-switch reduction — on the planner study's 4:1-tapered leaf–spine
//! fabric (racks of 8, contiguous placement).  Every point records
//! events executed, events/second, peak queue depth and wall-clock; at
//! the baselined node counts the same scenario is re-run on
//! [`EngineKind::BoxedBaseline`] (the PR-3 representation: one
//! `Box<dyn FnOnce>` per event on a `BinaryHeap`) so the speedup is
//! measured, not estimated.
//!
//! `smartnic engine-bench` prints the table and writes
//! `BENCH_engine.json` (schema documented in `docs/BENCHMARKS.md`,
//! pinned by `rust/tests/bench_schema.rs`).  The run fails (nonzero
//! exit) if the typed engine is not at least [`SPEEDUP_GATE`]x faster
//! than the baseline on the [`GATE_NODES`]-node NIC ring, or if the two
//! representations disagree on virtual time by more than
//! [`VIRTUAL_TIME_TOL`] anywhere.

use crate::analytic::model::SystemKind;
use crate::cluster::{
    run_scenario_on, ClusterSpec, CollectiveAlgo, EngineKind, JobSpec, ScenarioOutput, Topology,
};
use crate::experiments::planner::{leaf_shape, planner_system};
use crate::sysconfig::Workload;
use crate::util::json::Json;
use crate::util::stats::rel_err;
use crate::util::table::{fnum, Table};
use std::time::Instant;

/// Plan families benchmarked at every node count, in row order.
pub const ALGOS: [(&str, CollectiveAlgo); 3] = [
    ("nic-ring", CollectiveAlgo::NicRing),
    ("hierarchical", CollectiveAlgo::NicHierarchical),
    ("in-switch", CollectiveAlgo::SwitchReduce),
];

/// Wall-clock speedup the typed engine must reach over the boxed
/// baseline on the NIC ring at [`GATE_NODES`] nodes.
pub const SPEEDUP_GATE: f64 = 5.0;

/// Node count the speedup gate is pinned at (the PR-2 sweep's largest
/// point, where the boxed engine scheduled tens of millions of
/// closures).
pub const GATE_NODES: usize = 512;

/// Both representations must agree on every virtual-time result to this
/// relative tolerance (they execute the identical event order, so the
/// observed deviation is exactly zero).
pub const VIRTUAL_TIME_TOL: f64 = 1e-9;

/// Sweep parameters.
#[derive(Clone, Debug)]
pub struct EngineBenchConfig {
    /// node counts for the typed-engine sweep (even, >= 4)
    pub nodes: Vec<usize>,
    /// node counts additionally re-run on the boxed-closure baseline
    pub baseline_nodes: Vec<usize>,
    /// leaf uplink oversubscription factor
    pub oversubscription: f64,
    /// gradient width: hidden² elements per all-reduce
    pub hidden: usize,
}

impl Default for EngineBenchConfig {
    fn default() -> Self {
        Self {
            nodes: vec![128, 512, 2048],
            baseline_nodes: vec![128, 512],
            oversubscription: 4.0,
            hidden: 2048,
        }
    }
}

/// One (node count, plan family) cell of the benchmark.
#[derive(Clone, Debug)]
pub struct EnginePoint {
    pub nodes: usize,
    pub algo: &'static str,
    /// virtual makespan of the scenario (seconds of simulated time)
    pub virtual_s: f64,
    /// events executed by the typed engine
    pub events: u64,
    /// high-water mark of the typed engine's pending-event count
    pub peak_queue: usize,
    /// typed-engine wall-clock (seconds)
    pub wall_s: f64,
    /// typed-engine throughput
    pub events_per_sec: f64,
    /// boxed-closure baseline wall-clock (None when not baselined)
    pub baseline_wall_s: Option<f64>,
    pub baseline_events_per_sec: Option<f64>,
    /// baseline wall-clock over typed wall-clock
    pub speedup: Option<f64>,
    /// relative virtual-time deviation typed vs boxed
    pub virtual_err: Option<f64>,
}

/// The scenario a point runs: one `hidden`²-element all-reduce on the
/// planner study's provisioned leaf–spine fabric, contiguous placement.
fn bench_spec(n: usize, algo: CollectiveAlgo, cfg: &EngineBenchConfig) -> ClusterSpec {
    assert!(n >= 4 && n % 2 == 0, "engine bench needs even node counts >= 4, got {n}");
    let (leaves, m) = leaf_shape(n);
    let sys = planner_system(leaves, m);
    let topo = Topology::leaf_spine(leaves, m, cfg.oversubscription);
    let w = Workload {
        layers: 1,
        hidden: cfg.hidden,
        batch_per_node: 64,
    };
    ClusterSpec::new(sys, n).with_topology(topo).with_job(
        JobSpec::new("bench", SystemKind::SmartNic { bfp: false }, w, topo.contiguous_ranks(n))
            .with_layer_algos(vec![algo]),
    )
}

fn timed_run(spec: &ClusterSpec, engine: EngineKind) -> (ScenarioOutput, f64) {
    let t0 = Instant::now();
    let out = run_scenario_on(spec, engine);
    (out, t0.elapsed().as_secs_f64())
}

/// Run the full benchmark.
pub fn run(cfg: &EngineBenchConfig) -> Vec<EnginePoint> {
    let mut out = Vec::new();
    for &n in &cfg.nodes {
        for (name, algo) in ALGOS {
            let spec = bench_spec(n, algo, cfg);
            let (typed, wall) = timed_run(&spec, EngineKind::Typed);
            let mut point = EnginePoint {
                nodes: n,
                algo: name,
                virtual_s: typed.makespan,
                events: typed.events,
                peak_queue: typed.peak_queue_depth,
                wall_s: wall,
                events_per_sec: typed.events as f64 / wall.max(1e-12),
                baseline_wall_s: None,
                baseline_events_per_sec: None,
                speedup: None,
                virtual_err: None,
            };
            if cfg.baseline_nodes.contains(&n) {
                let (boxed, boxed_wall) = timed_run(&spec, EngineKind::BoxedBaseline);
                assert_eq!(
                    boxed.events, typed.events,
                    "engines diverged in event count at n={n} {name}"
                );
                point.baseline_wall_s = Some(boxed_wall);
                point.baseline_events_per_sec = Some(boxed.events as f64 / boxed_wall.max(1e-12));
                point.speedup = Some(boxed_wall / wall.max(1e-12));
                point.virtual_err = Some(rel_err(boxed.makespan, typed.makespan));
            }
            out.push(point);
        }
    }
    out
}

/// The gate measurement: typed-vs-boxed wall-clock speedup on the NIC
/// ring at [`GATE_NODES`] nodes.  `None` when the sweep holds no
/// baselined ring run there — the gate then has nothing to say and must
/// not report a vacuous PASS.
pub fn gate_speedup(points: &[EnginePoint]) -> Option<f64> {
    points
        .iter()
        .find(|p| p.nodes == GATE_NODES && p.algo == "nic-ring")
        .and_then(|p| p.speedup)
}

/// Worst typed-vs-boxed virtual-time deviation across baselined points.
pub fn worst_virtual_err(points: &[EnginePoint]) -> Option<f64> {
    points
        .iter()
        .filter_map(|p| p.virtual_err)
        .fold(None, |acc: Option<f64>, e| Some(acc.map_or(e, |a| a.max(e))))
}

/// Largest node count the sweep completed.
pub fn max_nodes_completed(points: &[EnginePoint]) -> usize {
    points.iter().map(|p| p.nodes).max().unwrap_or(0)
}

pub fn print(points: &[EnginePoint], cfg: &EngineBenchConfig) {
    let mut t = Table::new(&[
        "nodes",
        "algo",
        "events",
        "peak queue",
        "typed (s)",
        "Mev/s",
        "boxed (s)",
        "speedup",
    ])
    .with_title(&format!(
        "engine bench — typed arena vs boxed closures, hidden={} on {}:1 leaf-spine",
        cfg.hidden, cfg.oversubscription
    ));
    for p in points {
        t.row(&[
            p.nodes.to_string(),
            p.algo.to_string(),
            p.events.to_string(),
            p.peak_queue.to_string(),
            fnum(p.wall_s, 3),
            fnum(p.events_per_sec / 1e6, 2),
            p.baseline_wall_s.map_or("-".to_string(), |w| fnum(w, 3)),
            p.speedup.map_or("-".to_string(), |s| format!("x{}", fnum(s, 2))),
        ]);
    }
    t.print();
    match gate_speedup(points) {
        Some(s) => println!(
            "typed vs boxed on the {GATE_NODES}-node NIC ring: x{:.2} (gate x{SPEEDUP_GATE}) — {}",
            s,
            if s >= SPEEDUP_GATE { "PASS" } else { "FAIL" }
        ),
        None => println!(
            "speedup gate: not validated (no baselined {GATE_NODES}-node NIC ring in the sweep)"
        ),
    }
    match worst_virtual_err(points) {
        Some(e) => println!(
            "virtual-time parity typed vs boxed: worst {:.2e} (tol {VIRTUAL_TIME_TOL:.0e}) — {}",
            e,
            if e <= VIRTUAL_TIME_TOL { "PASS" } else { "FAIL" }
        ),
        None => println!("virtual-time parity: not validated (no baselined points)"),
    }
    println!("largest completed sweep: {} nodes", max_nodes_completed(points));
}

/// Serialize the benchmark to the `BENCH_engine.json` schema
/// (documented in `docs/BENCHMARKS.md`).
pub fn to_json(cfg: &EngineBenchConfig, points: &[EnginePoint]) -> Json {
    Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("hidden", Json::Num(cfg.hidden as f64)),
                ("oversubscription", Json::Num(cfg.oversubscription)),
                ("speedup_gate", Json::Num(SPEEDUP_GATE)),
                ("gate_nodes", Json::Num(GATE_NODES as f64)),
                ("virtual_time_tol", Json::Num(VIRTUAL_TIME_TOL)),
            ]),
        ),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        let baseline = match (p.baseline_wall_s, p.baseline_events_per_sec) {
                            (Some(wall), Some(eps)) => Json::obj(vec![
                                ("wall_s", Json::Num(wall)),
                                ("events_per_sec", Json::Num(eps)),
                                ("speedup", Json::Num(p.speedup.unwrap_or(0.0))),
                                ("virtual_err", Json::Num(p.virtual_err.unwrap_or(0.0))),
                            ]),
                            _ => Json::Null,
                        };
                        Json::obj(vec![
                            ("nodes", Json::Num(p.nodes as f64)),
                            ("algo", Json::Str(p.algo.to_string())),
                            ("virtual_s", Json::Num(p.virtual_s)),
                            ("events", Json::Num(p.events as f64)),
                            ("peak_queue_depth", Json::Num(p.peak_queue as f64)),
                            ("wall_s", Json::Num(p.wall_s)),
                            ("events_per_sec", Json::Num(p.events_per_sec)),
                            ("baseline", baseline),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "gates",
            Json::obj(vec![
                (
                    "ring_gate_speedup",
                    match gate_speedup(points) {
                        Some(s) => Json::Num(s),
                        None => Json::Null,
                    },
                ),
                (
                    "speedup_pass",
                    match gate_speedup(points) {
                        Some(s) => Json::Bool(s >= SPEEDUP_GATE),
                        None => Json::Null,
                    },
                ),
                (
                    "worst_virtual_err",
                    match worst_virtual_err(points) {
                        Some(e) => Json::Num(e),
                        None => Json::Null,
                    },
                ),
                ("max_nodes_completed", Json::Num(max_nodes_completed(points) as f64)),
            ]),
        ),
    ])
}

/// Write the benchmark to `path` (repo convention: `BENCH_engine.json`,
/// uploaded as a CI artifact).
pub fn write_bench(
    path: &str,
    cfg: &EngineBenchConfig,
    points: &[EnginePoint],
) -> std::io::Result<()> {
    std::fs::write(path, to_json(cfg, points).to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> EngineBenchConfig {
        EngineBenchConfig {
            nodes: vec![8],
            baseline_nodes: vec![8],
            oversubscription: 4.0,
            hidden: 128,
        }
    }

    #[test]
    fn tiny_sweep_produces_all_plan_families() {
        let points = run(&tiny_cfg());
        assert_eq!(points.len(), ALGOS.len());
        for p in &points {
            assert!(p.events > 0, "{}: no events", p.algo);
            assert!(p.virtual_s > 0.0 && p.virtual_s.is_finite());
            assert!(p.peak_queue > 0);
            assert!(p.speedup.is_some(), "{}: baseline missing", p.algo);
        }
    }

    #[test]
    fn engines_agree_on_virtual_time() {
        let points = run(&tiny_cfg());
        let worst = worst_virtual_err(&points).expect("baselined points exist");
        assert!(worst <= VIRTUAL_TIME_TOL, "virtual-time drift {worst}");
    }

    #[test]
    fn gate_is_not_vacuous_without_the_pinned_point() {
        let points = run(&tiny_cfg());
        assert!(gate_speedup(&points).is_none(), "8-node sweep cannot claim the 512-node gate");
        assert_eq!(max_nodes_completed(&points), 8);
    }
}
