//! E1 — Fig. 2a: training-iteration breakdown of naive vs overlapped host
//! all-reduce (6 nodes, 20-layer 2048² MLP, B=1792/node, 100 GbE).

use crate::analytic::model::SystemKind;
use crate::collective::Scheme;
use crate::coordinator::simulate_iteration;
use crate::sysconfig::{SystemParams, Workload};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

#[derive(Clone, Debug)]
pub struct Row {
    pub name: String,
    pub t_fwd: f64,
    pub t_bwd: f64,
    pub t_exposed_ar: f64,
    pub t_update: f64,
    pub t_total: f64,
}

pub fn run(nodes: usize, batch: usize) -> Vec<Row> {
    let sys = SystemParams::baseline_100g();
    let w = Workload::paper_mlp(batch);
    [
        ("naive", SystemKind::BaselineNaive { scheme: Scheme::Ring }),
        (
            "overlapped (k=2)",
            SystemKind::BaselineOverlapped {
                scheme: Scheme::Ring,
                comm_cores: 2,
            },
        ),
    ]
    .into_iter()
    .map(|(name, kind)| {
        let bd = simulate_iteration(kind, &sys, &w, nodes).breakdown;
        Row {
            name: name.to_string(),
            t_fwd: bd.t_fwd,
            t_bwd: bd.t_bwd,
            t_exposed_ar: bd.t_exposed_ar,
            t_update: bd.t_update,
            t_total: bd.t_total,
        }
    })
    .collect()
}

pub fn print(rows: &[Row]) {
    let mut t = Table::new(&[
        "implementation",
        "fwd (ms)",
        "bwd (ms)",
        "exposed AR (ms)",
        "update (ms)",
        "total (ms)",
        "AR share",
    ])
    .with_title(
        "Fig. 2a — iteration breakdown, 20-layer 2048^2 MLP, B=1792/node, 6 nodes (baseline NICs)",
    );
    for r in rows {
        t.row(&[
            r.name.clone(),
            fnum(r.t_fwd * 1e3, 1),
            fnum(r.t_bwd * 1e3, 1),
            fnum(r.t_exposed_ar * 1e3, 1),
            fnum(r.t_update * 1e3, 1),
            fnum(r.t_total * 1e3, 1),
            format!("{:.0}%", 100.0 * r.t_exposed_ar / r.t_total),
        ]);
    }
    t.print();
    let speedup = rows[0].t_total / rows[1].t_total;
    let ar_ratio = rows[0].t_exposed_ar / rows[1].t_exposed_ar.max(1e-12);
    println!(
        "overlap speedup: {speedup:.2}x (paper: 1.85x); exposed-AR reduction: {ar_ratio:.0}x (paper: ~50x)\n"
    );
}

pub fn to_json(rows: &[Row]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("t_fwd", Json::Num(r.t_fwd)),
                    ("t_bwd", Json::Num(r.t_bwd)),
                    ("t_exposed_ar", Json::Num(r.t_exposed_ar)),
                    ("t_update", Json::Num(r.t_update)),
                    ("t_total", Json::Num(r.t_total)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn papers_shape_holds() {
        let rows = run(6, 1792);
        assert_eq!(rows.len(), 2);
        // naive: ~51% exposed AR
        let frac = rows[0].t_exposed_ar / rows[0].t_total;
        assert!((0.4..0.6).contains(&frac), "naive AR share {frac}");
        // overlap wins by ~1.85x
        let speedup = rows[0].t_total / rows[1].t_total;
        assert!((1.5..2.2).contains(&speedup), "speedup {speedup}");
        // overlapped bwd is slower (the shaded black bar)
        assert!(rows[1].t_bwd > rows[0].t_bwd);
    }

    #[test]
    fn json_roundtrip() {
        let rows = run(3, 448);
        let j = to_json(&rows);
        assert_eq!(j.as_arr().unwrap().len(), 2);
    }
}
