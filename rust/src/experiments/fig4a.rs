//! E4 — Fig. 4a: iteration breakdown at 6 nodes (B=448): baseline vs
//! smart NIC vs smart NIC + BFP.

use crate::analytic::model::SystemKind;
use crate::collective::Scheme;
use crate::coordinator::simulate_iteration;
use crate::sysconfig::{SystemParams, Workload};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

pub use super::fig2a::Row;

pub fn run(nodes: usize, batch: usize) -> Vec<Row> {
    let w = Workload::paper_mlp(batch);
    let variants: [(&str, SystemKind, SystemParams); 3] = [
        (
            "baseline (overlapped)",
            SystemKind::BaselineOverlapped {
                scheme: Scheme::Ring,
                comm_cores: 2,
            },
            SystemParams::baseline_100g(),
        ),
        (
            "AI smart NIC",
            SystemKind::SmartNic { bfp: false },
            SystemParams::smartnic_40g(),
        ),
        (
            "AI smart NIC + BFP",
            SystemKind::SmartNic { bfp: true },
            SystemParams::smartnic_40g(),
        ),
    ];
    variants
        .into_iter()
        .map(|(name, kind, sys)| {
            let bd = simulate_iteration(kind, &sys, &w, nodes).breakdown;
            Row {
                name: name.to_string(),
                t_fwd: bd.t_fwd,
                t_bwd: bd.t_bwd,
                t_exposed_ar: bd.t_exposed_ar,
                t_update: bd.t_update,
                t_total: bd.t_total,
            }
        })
        .collect()
}

pub fn print(rows: &[Row]) {
    let mut t = Table::new(&[
        "system",
        "fwd (ms)",
        "bwd (ms)",
        "exposed AR (ms)",
        "update (ms)",
        "total (ms)",
        "vs baseline",
    ])
    .with_title("Fig. 4a — iteration breakdown, 20-layer 2048^2 MLP, B=448/node, 6 nodes");
    for r in rows {
        t.row(&[
            r.name.clone(),
            fnum(r.t_fwd * 1e3, 1),
            fnum(r.t_bwd * 1e3, 1),
            fnum(r.t_exposed_ar * 1e3, 1),
            fnum(r.t_update * 1e3, 1),
            fnum(r.t_total * 1e3, 1),
            format!("{:+.0}%", 100.0 * (r.t_total / rows[0].t_total - 1.0)),
        ]);
    }
    t.print();
    println!(
        "exposed-AR change: NIC {:+.0}% (paper -37%), NIC+BFP {:+.0}% (paper -95%)\n",
        100.0 * (rows[1].t_exposed_ar / rows[0].t_exposed_ar - 1.0),
        100.0 * (rows[2].t_exposed_ar / rows[0].t_exposed_ar - 1.0),
    );
}

pub fn to_json(rows: &[Row]) -> Json {
    super::fig2a::to_json(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn papers_shape_holds() {
        let rows = run(6, 448);
        // ordering: baseline > NIC > NIC+BFP in total time
        assert!(rows[0].t_total > rows[1].t_total);
        assert!(rows[1].t_total > rows[2].t_total);
        // NIC reduces total by ~18% (accept 10-30%)
        let red_nic = 1.0 - rows[1].t_total / rows[0].t_total;
        assert!((0.10..0.30).contains(&red_nic), "nic {red_nic}");
        // NIC+BFP reduces total by ~40% (accept 30-50%)
        let red_bfp = 1.0 - rows[2].t_total / rows[0].t_total;
        assert!((0.30..0.50).contains(&red_bfp), "bfp {red_bfp}");
        // NIC frees worker resources: bwd drops ~10%
        let bwd_drop = 1.0 - rows[1].t_bwd / rows[0].t_bwd;
        assert!((0.05..0.25).contains(&bwd_drop), "bwd {bwd_drop}");
        // exposed AR falls monotonically, dramatically with BFP
        assert!(rows[1].t_exposed_ar < rows[0].t_exposed_ar);
        assert!(rows[2].t_exposed_ar < 0.5 * rows[0].t_exposed_ar);
    }
}
