//! E10 — multi-tenant in-switch contention study: tenants × table sizes
//! × PFC pause rates on one shared leaf–spine reduction tier.
//!
//! Up to four disjoint 8-rank jobs (two ranks in each of four leaves, so
//! every job folds through the *same* spine engine and aggregation
//! table) post concurrent all-reduces under `CollectiveAlgo::Auto`.  Each
//! tenant is priced by the planner against the switch tier's *current*
//! occupancy ([`planner::TenancyLoad`]), then admitted per flow by the
//! finite [`TableAllocator`].  The study records, per grid point, how the
//! admission outcomes partition the tenants and where the planner flips
//! from in-switch reduction to its NIC-ring/hierarchical fallback — the
//! *occupancy knee*.
//!
//! `smartnic tenancy` prints the table and writes `BENCH_tenancy.json`;
//! the run fails (nonzero exit) unless (a) at the documented default
//! point (max tenants, table scale 1.0, no pause) the solo tenant wins
//! in-switch and a later tenant is refused — a knee at tenant ≥ 2, (b)
//! saturating pause pressure moves the knee no later, (c) an audited
//! `Checked {4}` re-run of the default point is violation-free and
//! bit-identical, and (d) a same-seed re-run reproduces the knee and
//! makespan bit-for-bit.
//!
//! [`planner::TenancyLoad`]: crate::cluster::planner::TenancyLoad
//! [`TableAllocator`]: crate::netsim::switch::TableAllocator

use crate::analytic::model::SystemKind;
use crate::cluster::{
    run_scenario, run_scenario_on, ClusterSpec, CollectiveAlgo, EngineKind, JobSpec, Topology,
};
use crate::sysconfig::{PfcParams, SwitchParams, SystemParams, Workload};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

/// Fabric shape: every tenant spans all four leaves with two ranks each.
pub const LEAVES: usize = 4;
pub const NODES_PER_LEAF: usize = 8;

/// Aggregation-table capacity at `table_scale = 1.0`.
pub const BASE_TABLE_BYTES: f64 = 8.0 * 1024.0 * 1024.0;

/// Duration of one PFC pause window (s); the sweep varies the rate.
pub const PAUSE_WINDOW_S: f64 = 1.0e-3;

/// Sweep parameters.
#[derive(Clone, Debug)]
pub struct TenancyConfig {
    /// concurrent tenant counts (≤ [`NODES_PER_LEAF`]/2 so placements
    /// stay disjoint)
    pub tenant_counts: Vec<usize>,
    /// aggregation-table capacities, as multiples of [`BASE_TABLE_BYTES`]
    pub table_scales: Vec<f64>,
    /// PFC pause assertions per second (window fixed at
    /// [`PAUSE_WINDOW_S`])
    pub pause_rates: Vec<f64>,
    /// gradient width: hidden² elements per all-reduce
    pub hidden: usize,
    /// leaf uplink oversubscription factor
    pub oversubscription: f64,
}

impl Default for TenancyConfig {
    fn default() -> Self {
        Self {
            tenant_counts: vec![1, 2, 3, 4],
            // 1/64 × base sits below one 256 KiB segment: even a solo
            // tenant is refused (PR 3's per-flow fallback), pinning the
            // degenerate end of the knee curve
            table_scales: vec![1.0 / 64.0, 1.0, 4.0],
            pause_rates: vec![0.0, 100.0, 800.0],
            hidden: 1024,
            oversubscription: 4.0,
        }
    }
}

/// One grid point: a full multi-tenant scenario at fixed (tenants, table
/// scale, pause rate).
#[derive(Clone, Debug)]
pub struct TenancyPoint {
    pub tenants: usize,
    pub table_scale: f64,
    pub table_bytes: f64,
    pub pause_rate: f64,
    pub pfc_duty: f64,
    /// per-tenant admission outcome, in post (= job) order
    pub outcomes: Vec<&'static str>,
    /// 1-based index of the first tenant *not* admitted in-switch;
    /// `None` when every tenant was admitted
    pub knee: Option<usize>,
    pub admitted: usize,
    pub evicted: usize,
    pub fallback: usize,
    /// sticky-idle table slots displaced by competing tenants
    pub table_evictions: u64,
    pub makespan: f64,
    /// mean AR latency of the first-posted tenant (s)
    pub mean_ar_first: f64,
    /// mean AR latency of the last-posted tenant (s)
    pub mean_ar_last: f64,
}

/// The knee-defining gates, computed once per study.
#[derive(Clone, Copy, Debug)]
pub struct TenancyGates {
    /// the knee at the documented default point (max tenants, scale 1.0,
    /// no pause); `None` when the grid does not contain that point
    pub knee_default: Option<Option<usize>>,
    /// solo tenant admitted in-switch at (1, 1.0, 0.0)
    pub solo_inswitch_wins: Option<bool>,
    /// knee at the max pause rate no later than the unpaused knee
    pub pause_collapses_knee: Option<bool>,
    /// audited `Checked {4}` re-run of the default point: zero
    /// violations and bit-identical makespan
    pub audited_clean: bool,
    /// same-seed re-run reproduces knee and makespan bit-for-bit
    pub deterministic: bool,
}

impl TenancyGates {
    /// Overall verdict: every stated gate passes (a gate whose grid
    /// point is missing reports `None` above and fails here — the study
    /// must not pass vacuously).
    pub fn pass(&self) -> bool {
        matches!(self.knee_default, Some(Some(k)) if k >= 2)
            && self.solo_inswitch_wins == Some(true)
            && self.pause_collapses_knee == Some(true)
            && self.audited_clean
            && self.deterministic
    }
}

/// The shared-tier system under test: a NetReduce-provisioned switch
/// whose table capacity is overridden to `BASE_TABLE_BYTES × scale`,
/// with the given PFC pause pattern.
pub fn tenancy_system(table_scale: f64, pause_rate: f64) -> SystemParams {
    let base = SystemParams::smartnic_40g();
    let mut switch = SwitchParams::netreduce(NODES_PER_LEAF, &base.net);
    switch.reduce_table_bytes = BASE_TABLE_BYTES * table_scale;
    base.with_switch_reduction(switch).with_pfc(PfcParams {
        pause_rate,
        pause_window: PAUSE_WINDOW_S,
    })
}

/// Tenant `j`'s placement: ranks `{8l + 2j, 8l + 2j + 1}` in every leaf
/// `l` — disjoint across tenants, all spanning, all rooted in leaf 0, so
/// every tenant folds through the same spine engine.
pub fn tenant_ranks(j: usize) -> Vec<usize> {
    assert!(2 * (j + 1) <= NODES_PER_LEAF, "tenant {j} does not fit the leaves");
    (0..LEAVES)
        .flat_map(|l| [l * NODES_PER_LEAF + 2 * j, l * NODES_PER_LEAF + 2 * j + 1])
        .collect()
}

/// The scenario of one grid point: `tenants` identical single-layer jobs
/// posting at t = 0 under `Auto`, in deterministic job order.
pub fn point_spec(cfg: &TenancyConfig, tenants: usize, scale: f64, rate: f64) -> ClusterSpec {
    let sys = tenancy_system(scale, rate);
    let topo = Topology::leaf_spine(LEAVES, NODES_PER_LEAF, cfg.oversubscription);
    let w = Workload {
        layers: 1,
        hidden: cfg.hidden,
        batch_per_node: 64,
    };
    let mut spec = ClusterSpec::new(sys, topo.nodes()).with_topology(topo);
    for j in 0..tenants {
        spec = spec.with_job(
            JobSpec::new(
                &format!("tenant{j}"),
                SystemKind::SmartNic { bfp: false },
                w,
                tenant_ranks(j),
            )
            .with_layer_algos(vec![CollectiveAlgo::Auto]),
        );
    }
    spec
}

fn outcome_name(t: &crate::cluster::TenancyStats) -> &'static str {
    if t.admitted > 0 {
        "admitted"
    } else if t.evicted > 0 {
        "evicted"
    } else if t.fallback > 0 {
        "fallback"
    } else {
        "not-requested"
    }
}

/// Run one grid point on the production engine.
pub fn run_point(cfg: &TenancyConfig, tenants: usize, scale: f64, rate: f64) -> TenancyPoint {
    let spec = point_spec(cfg, tenants, scale, rate);
    let out = run_scenario(&spec);
    let outcomes: Vec<&'static str> =
        out.jobs.iter().map(|j| outcome_name(&j.tenancy)).collect();
    let knee = outcomes.iter().position(|&o| o != "admitted").map(|i| i + 1);
    TenancyPoint {
        tenants,
        table_scale: scale,
        table_bytes: BASE_TABLE_BYTES * scale,
        pause_rate: rate,
        pfc_duty: spec.sys.pfc.duty(),
        outcomes,
        knee,
        admitted: out.tenancy.admitted,
        evicted: out.tenancy.evicted,
        fallback: out.tenancy.fallback,
        table_evictions: out.tenancy.table_evictions,
        makespan: out.makespan,
        mean_ar_first: out.jobs[0].mean_ar,
        mean_ar_last: out.jobs[out.jobs.len() - 1].mean_ar,
    }
}

/// Run the full grid, row-major in (scale, rate, tenants) order.
pub fn run(cfg: &TenancyConfig) -> Vec<TenancyPoint> {
    let mut out = Vec::new();
    for &scale in &cfg.table_scales {
        for &rate in &cfg.pause_rates {
            for &tenants in &cfg.tenant_counts {
                out.push(run_point(cfg, tenants, scale, rate));
            }
        }
    }
    out
}

fn point_at(
    points: &[TenancyPoint],
    tenants: usize,
    scale: f64,
    rate: f64,
) -> Option<&TenancyPoint> {
    points
        .iter()
        .find(|p| p.tenants == tenants && p.table_scale == scale && p.pause_rate == rate)
}

/// Compute every gate.  The knee/solo/pause gates read the already-run
/// grid (and report `None` when the grid lacks their point — never a
/// vacuous pass); the audit and determinism gates re-run the default
/// point themselves.
pub fn gates(cfg: &TenancyConfig, points: &[TenancyPoint]) -> TenancyGates {
    let max_tenants = cfg.tenant_counts.iter().copied().max().unwrap_or(0);
    let max_rate =
        cfg.pause_rates.iter().copied().fold(0.0f64, f64::max);
    let default_point = point_at(points, max_tenants, 1.0, 0.0);
    let knee_default = default_point.map(|p| p.knee);
    let solo_inswitch_wins =
        point_at(points, 1, 1.0, 0.0).map(|p| p.outcomes == ["admitted"]);
    let pause_collapses_knee = match (default_point, point_at(points, max_tenants, 1.0, max_rate))
    {
        (Some(calm), Some(stormy)) if max_rate > 0.0 => {
            // a missing knee means "never refused" — later than any index
            let at = |p: &TenancyPoint| p.knee.unwrap_or(usize::MAX);
            Some(at(stormy) <= at(calm))
        }
        _ => None,
    };
    let (audited_clean, deterministic) = match default_point {
        Some(p) => {
            let spec = point_spec(cfg, p.tenants, p.table_scale, p.pause_rate);
            let checked = run_scenario_on(&spec, EngineKind::Checked { threads: 4 });
            let clean = checked
                .audit
                .as_ref()
                .is_some_and(|r| r.is_clean())
                && checked.makespan.to_bits() == p.makespan.to_bits();
            let rerun = run_point(cfg, p.tenants, p.table_scale, p.pause_rate);
            let stable = rerun.knee == p.knee
                && rerun.outcomes == p.outcomes
                && rerun.makespan.to_bits() == p.makespan.to_bits();
            (clean, stable)
        }
        None => (false, false),
    };
    TenancyGates {
        knee_default,
        solo_inswitch_wins,
        pause_collapses_knee,
        audited_clean,
        deterministic,
    }
}

pub fn print(points: &[TenancyPoint], cfg: &TenancyConfig, g: &TenancyGates) {
    let mut t = Table::new(&[
        "tenants",
        "table",
        "pause/s",
        "duty",
        "outcomes",
        "knee",
        "evictions",
        "ar first (ms)",
        "ar last (ms)",
        "makespan (ms)",
    ])
    .with_title(&format!(
        "tenancy study — {LEAVES}x{NODES_PER_LEAF} leaf-spine at {}:1, shared spine reduction tier",
        cfg.oversubscription
    ));
    for p in points {
        t.row(&[
            p.tenants.to_string(),
            format!("{}x", fnum(p.table_scale, 3)),
            fnum(p.pause_rate, 0),
            fnum(p.pfc_duty, 2),
            p.outcomes.join(","),
            p.knee.map_or("-".to_string(), |k| k.to_string()),
            p.table_evictions.to_string(),
            fnum(p.mean_ar_first * 1e3, 2),
            fnum(p.mean_ar_last * 1e3, 2),
            fnum(p.makespan * 1e3, 2),
        ]);
    }
    t.print();
    match g.knee_default {
        Some(Some(k)) => println!(
            "occupancy knee at the default point: tenant {k} refused — {}",
            if k >= 2 { "PASS" } else { "FAIL (in-switch never won)" }
        ),
        Some(None) => println!("occupancy knee at the default point: none — FAIL (never flips)"),
        None => println!("occupancy knee: not validated (default point not in the sweep) — FAIL"),
    }
    let yn = |b: Option<bool>| match b {
        Some(true) => "PASS",
        Some(false) => "FAIL",
        None => "not validated — FAIL",
    };
    println!("solo tenant wins in-switch: {}", yn(g.solo_inswitch_wins));
    println!("pause pressure moves the knee no later: {}", yn(g.pause_collapses_knee));
    println!(
        "audited Checked{{4}} re-run clean and bit-identical: {}",
        if g.audited_clean { "PASS" } else { "FAIL" }
    );
    println!(
        "same-seed re-run reproduces the knee bit-for-bit: {}",
        if g.deterministic { "PASS" } else { "FAIL" }
    );
}

/// Serialize the study to the `BENCH_tenancy.json` schema (pinned by
/// `rust/tests/bench_schema.rs`, documented in `docs/BENCHMARKS.md`).
pub fn to_json(cfg: &TenancyConfig, points: &[TenancyPoint], g: &TenancyGates) -> Json {
    let opt_num = |v: Option<usize>| match v {
        Some(k) => Json::Num(k as f64),
        None => Json::Null,
    };
    let opt_bool = |v: Option<bool>| match v {
        Some(b) => Json::Bool(b),
        None => Json::Null,
    };
    Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("leaves", Json::Num(LEAVES as f64)),
                ("nodes_per_leaf", Json::Num(NODES_PER_LEAF as f64)),
                ("oversubscription", Json::Num(cfg.oversubscription)),
                ("hidden", Json::Num(cfg.hidden as f64)),
                ("base_table_bytes", Json::Num(BASE_TABLE_BYTES)),
                ("pause_window_s", Json::Num(PAUSE_WINDOW_S)),
                (
                    "tenant_counts",
                    Json::Arr(cfg.tenant_counts.iter().map(|&t| Json::Num(t as f64)).collect()),
                ),
                (
                    "table_scales",
                    Json::Arr(cfg.table_scales.iter().map(|&s| Json::Num(s)).collect()),
                ),
                (
                    "pause_rates",
                    Json::Arr(cfg.pause_rates.iter().map(|&r| Json::Num(r)).collect()),
                ),
            ]),
        ),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("tenants", Json::Num(p.tenants as f64)),
                            ("table_scale", Json::Num(p.table_scale)),
                            ("table_bytes", Json::Num(p.table_bytes)),
                            ("pause_rate", Json::Num(p.pause_rate)),
                            ("pfc_duty", Json::Num(p.pfc_duty)),
                            (
                                "outcomes",
                                Json::Arr(
                                    p.outcomes
                                        .iter()
                                        .map(|o| Json::Str(o.to_string()))
                                        .collect(),
                                ),
                            ),
                            ("knee", opt_num(p.knee)),
                            ("admitted", Json::Num(p.admitted as f64)),
                            ("evicted", Json::Num(p.evicted as f64)),
                            ("fallback", Json::Num(p.fallback as f64)),
                            ("table_evictions", Json::Num(p.table_evictions as f64)),
                            ("makespan_s", Json::Num(p.makespan)),
                            ("mean_ar_first_s", Json::Num(p.mean_ar_first)),
                            ("mean_ar_last_s", Json::Num(p.mean_ar_last)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "gates",
            Json::obj(vec![
                (
                    "knee_default",
                    match g.knee_default {
                        Some(k) => opt_num(k),
                        None => Json::Null,
                    },
                ),
                ("solo_inswitch_wins", opt_bool(g.solo_inswitch_wins)),
                ("pause_collapses_knee", opt_bool(g.pause_collapses_knee)),
                ("audited_clean", Json::Bool(g.audited_clean)),
                ("deterministic", Json::Bool(g.deterministic)),
                ("pass", Json::Bool(g.pass())),
            ]),
        ),
    ])
}

/// Write the study to `path` (repo convention: `BENCH_tenancy.json`,
/// uploaded as a CI artifact).
pub fn write_bench(
    path: &str,
    cfg: &TenancyConfig,
    points: &[TenancyPoint],
    g: &TenancyGates,
) -> std::io::Result<()> {
    std::fs::write(path, to_json(cfg, points, g).to_string_pretty())
}

#[cfg(test)]
// exact float comparisons pin bit-identical determinism
#[allow(clippy::float_cmp)]
mod tests {
    use super::*;

    /// The default grid restricted to its gate-bearing column.
    fn gate_cfg() -> TenancyConfig {
        TenancyConfig {
            tenant_counts: vec![1, 4],
            table_scales: vec![1.0],
            pause_rates: vec![0.0, 800.0],
            ..TenancyConfig::default()
        }
    }

    #[test]
    fn default_point_passes_every_gate() {
        let cfg = gate_cfg();
        let pts = run(&cfg);
        let g = gates(&cfg, &pts);
        assert_eq!(g.solo_inswitch_wins, Some(true), "solo tenant must win in-switch");
        let knee = g.knee_default.expect("default point swept").expect("knee must exist");
        assert!(knee >= 2, "in-switch must win uncontended (knee {knee})");
        assert_eq!(g.pause_collapses_knee, Some(true));
        assert!(g.audited_clean, "Checked{{4}} re-run must be clean and bit-identical");
        assert!(g.deterministic, "same-seed re-run must reproduce the knee");
        assert!(g.pass());
    }

    #[test]
    fn admission_outcomes_partition_the_tenants() {
        let cfg = gate_cfg();
        for p in run(&cfg) {
            assert_eq!(p.outcomes.len(), p.tenants);
            // every tenant lands in exactly one bucket ("not-requested"
            // only when the planner priced in-switch out before asking)
            let classified = p.admitted
                + p.evicted
                + p.fallback
                + p.outcomes.iter().filter(|&&o| o == "not-requested").count();
            assert_eq!(classified, p.tenants);
        }
    }

    #[test]
    fn gates_refuse_to_pass_on_a_gridless_sweep() {
        // a grid without the default point must report None, not PASS
        let cfg = TenancyConfig {
            tenant_counts: vec![2],
            table_scales: vec![4.0],
            pause_rates: vec![0.0],
            ..TenancyConfig::default()
        };
        let pts = run(&cfg);
        let g = gates(&cfg, &pts);
        assert!(g.knee_default.is_none());
        assert!(g.solo_inswitch_wins.is_none());
        assert!(!g.pass());
    }

    #[test]
    fn bench_json_round_trips() {
        let cfg = TenancyConfig {
            tenant_counts: vec![1, 2],
            table_scales: vec![1.0],
            pause_rates: vec![0.0],
            ..TenancyConfig::default()
        };
        let pts = run(&cfg);
        let g = gates(&cfg, &pts);
        let j = to_json(&cfg, &pts, &g);
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed, j);
        let first = j.get("points").unwrap().idx(0).unwrap();
        assert_eq!(first.get("tenants").unwrap().as_usize(), Some(1));
        assert!(first.get("makespan_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("gates").unwrap().get("knee_default").is_some());
    }
}
