//! E9 — collective zoo: broadcast / allgather / reduce-scatter /
//! all-to-all as first-class planned collectives, next to their closed
//! forms.
//!
//! For every node count and both fabric shapes (flat crossbar and a
//! tapered leaf–spine), each collective kind runs through the kind-aware
//! planner on the unified engine — once per candidate plan the planner
//! prices, so a broadcast appears both as the host binomial tree and as
//! switch multicast (the replication dual of in-switch reduction).  Two
//! workload scenarios ride along: an MoE-style iteration interleaving an
//! all-to-all with an all-reduce, and an inference weight broadcast from
//! one source to every replica over the spine.
//!
//! `smartnic collectives` prints the table and writes
//! `BENCH_collectives.json`; the run fails (nonzero exit) when a gated
//! cell's closed form drifts ≥ 5% from the engine at the pinned node
//! counts, or switch multicast loses to the binomial tree at N ≥ 32 on
//! the leaf–spine.  All-to-all on the leaf–spine is reported but *not*
//! gated: its rounds put up to `nodes_per_leaf` concurrent flows on one
//! uplink bundle, and the engine's FIFO cut-through queueing prices that
//! convergence above the planner's fluid max-load bound (the documented
//! gap — see `docs/BENCHMARKS.md`).

use super::planner::{leaf_shape, planner_system};
use crate::analytic::model::SystemKind;
use crate::cluster::planner::{self, PlanKind};
use crate::cluster::{
    run_scenario_on, ClusterSpec, CollectiveAlgo, CollectiveKind, EngineKind, JobSpec, Topology,
};
use crate::netsim::audit::AuditReport;
use crate::sysconfig::{SystemParams, Workload};
use crate::util::json::Json;
use crate::util::stats::rel_err;
use crate::util::table::{fnum, Table};

/// The four non-all-reduce collectives the zoo sweeps (all-reduce keeps
/// its own study in `BENCH_planner.json`).
pub const KINDS: [CollectiveKind; 4] = [
    CollectiveKind::Broadcast,
    CollectiveKind::Allgather,
    CollectiveKind::ReduceScatter,
    CollectiveKind::AllToAll,
];

/// Node counts whose closed forms are pinned to the engine.
pub const PINNED_NODES: [usize; 3] = [6, 32, 128];

/// Tolerance of a gated closed form vs the unified engine.
pub const PARITY_TOL: f64 = 0.05;

/// Sweep parameters.
#[derive(Clone, Debug)]
pub struct CollectivesConfig {
    /// node counts (even, ≥ 4; racked by [`leaf_shape`])
    pub nodes: Vec<usize>,
    /// leaf uplink oversubscription factor.  The default of 2 keeps
    /// `nodes_per_leaf / oversubscription ≥ 1` for every swept shape, so
    /// a single-crossing round is paced by the sender's Tx link and the
    /// planner's max-load bound is exact; all-to-all still converges
    /// enough flows per bundle to expose the queueing gap.
    pub oversubscription: f64,
    /// payload width: hidden² elements per collective
    pub hidden: usize,
    /// engine backend every measurement runs on ([`EngineKind::Checked`]
    /// arms the invariant auditor)
    pub engine: EngineKind,
}

impl Default for CollectivesConfig {
    fn default() -> Self {
        Self {
            nodes: vec![6, 32, 128],
            oversubscription: 2.0,
            hidden: 1024,
            engine: EngineKind::Typed,
        }
    }
}

/// One (kind, plan, topology, node count) cell of the study.
#[derive(Clone, Debug)]
pub struct CollectivePoint {
    /// collective pattern ([`CollectiveKind::name`])
    pub kind: &'static str,
    pub nodes: usize,
    /// `"flat"` or `"leaf-spine"`
    pub topology: &'static str,
    /// plan family executed ([`PlanKind::name`])
    pub plan: &'static str,
    /// planner's closed-form prediction (s)
    pub model_s: f64,
    /// measured engine latency, post → completion (s)
    pub measured_s: f64,
    /// did `Auto` pick this plan for the cell?
    pub chosen: bool,
    /// hard 5%-parity cell (false only for all-to-all over the spine)
    pub gated: bool,
}

/// One workload scenario (several collectives composed into a job).
#[derive(Clone, Debug)]
pub struct ScenarioPoint {
    /// `"moe"` or `"weight-broadcast"`
    pub name: &'static str,
    pub nodes: usize,
    /// job duration on the engine (s)
    pub duration_s: f64,
    /// mean collective latency inside the job (s)
    pub mean_collective_s: f64,
    /// collectives the job completed
    pub collectives: usize,
}

/// Everything the study produces.
pub struct CollectivesStudy {
    pub points: Vec<CollectivePoint>,
    pub scenarios: Vec<ScenarioPoint>,
    /// `None` on unchecked engines, `Some(true)` when every audited run
    /// came back clean
    pub audit_clean: Option<bool>,
    /// summaries of the audit reports that were not clean
    pub audit_failures: Vec<String>,
}

/// Fold one run's audit report into the study-level verdict.
fn fold_audit(
    clean: &mut Option<bool>,
    failures: &mut Vec<String>,
    label: String,
    report: Option<AuditReport>,
) {
    if let Some(report) = report {
        let ok = report.is_clean();
        *clean = Some(clean.unwrap_or(true) && ok);
        if !ok {
            failures.push(format!("{label}: {}", report.summary()));
        }
    }
}

/// Run one single-collective job of `kind` under `algo` and return its
/// measured latency plus the engine's audit report (checked engines
/// only).
pub fn measure_collective(
    sys: SystemParams,
    topo: Topology,
    ranks: Vec<usize>,
    kind: CollectiveKind,
    algo: CollectiveAlgo,
    hidden: usize,
    engine: EngineKind,
) -> (f64, Option<AuditReport>) {
    let w = Workload {
        layers: 1,
        hidden,
        batch_per_node: 64,
    };
    let spec = ClusterSpec::new(sys, topo.nodes())
        .with_topology(topo)
        .with_job(
            JobSpec::new("coll", SystemKind::SmartNic { bfp: false }, w, ranks)
                .with_layer_algos(vec![algo])
                .with_layer_kinds(vec![kind]),
        );
    let out = run_scenario_on(&spec, engine);
    (out.jobs[0].mean_ar, out.audit)
}

/// The algorithm request that pins the planner to `plan` for a
/// non-all-reduce kind: `SwitchReduce` selects the switch offload, any
/// NIC-path algorithm the canonical host/NIC rounds plan.
fn algo_for_plan(plan: PlanKind) -> CollectiveAlgo {
    match plan {
        PlanKind::SwitchMulticast => CollectiveAlgo::SwitchReduce,
        _ => CollectiveAlgo::NicBinomial,
    }
}

/// Run the full study.
pub fn run(cfg: &CollectivesConfig) -> CollectivesStudy {
    let elems = cfg.hidden * cfg.hidden;
    let mut points = Vec::new();
    let mut scenarios = Vec::new();
    let mut audit_clean = None;
    let mut audit_failures = Vec::new();
    for &n in &cfg.nodes {
        assert!(n >= 4 && n % 2 == 0, "collective sweep needs even node counts >= 4, got {n}");
        let (leaves, m) = leaf_shape(n);
        let sys = planner_system(leaves, m);
        let spine = Topology::leaf_spine(leaves, m, cfg.oversubscription);
        let cells: [(&'static str, Topology, Vec<usize>); 2] = [
            ("flat", Topology::flat(n), (0..n).collect()),
            ("leaf-spine", spine, spine.contiguous_ranks(n)),
        ];
        for (topo_name, topo, ranks) in cells {
            for kind in KINDS {
                let cands = planner::candidates_for(&sys, &topo, &ranks, elems, 1.0, kind);
                let best = cands
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.predicted.total_cmp(&b.1.predicted))
                    .map(|(i, _)| i)
                    .expect("every kind has a host-path candidate");
                for (ci, cand) in cands.iter().enumerate() {
                    let (measured, report) = measure_collective(
                        sys,
                        topo,
                        ranks.clone(),
                        kind,
                        algo_for_plan(cand.kind),
                        cfg.hidden,
                        cfg.engine,
                    );
                    fold_audit(
                        &mut audit_clean,
                        &mut audit_failures,
                        format!("{} {} n={n} {}", kind.name(), cand.kind.name(), topo_name),
                        report,
                    );
                    points.push(CollectivePoint {
                        kind: kind.name(),
                        nodes: n,
                        topology: topo_name,
                        plan: cand.kind.name(),
                        model_s: cand.predicted,
                        measured_s: measured,
                        chosen: ci == best,
                        gated: !(kind == CollectiveKind::AllToAll && topo_name == "leaf-spine"),
                    });
                }
            }
        }

        // scenario 1 — MoE iteration: expert dispatch (all-to-all)
        // interleaved with the dense gradient all-reduce, planner-routed
        let moe_w = Workload {
            layers: 2,
            hidden: cfg.hidden,
            batch_per_node: 64,
        };
        let moe = ClusterSpec::new(sys, n).with_topology(spine).with_job(
            JobSpec::new("moe", SystemKind::SmartNic { bfp: false }, moe_w, spine.contiguous_ranks(n))
                .with_layer_algos(vec![CollectiveAlgo::Auto; 2])
                .with_layer_kinds(vec![CollectiveKind::AllToAll, CollectiveKind::AllReduce]),
        );
        let out = run_scenario_on(&moe, cfg.engine);
        fold_audit(&mut audit_clean, &mut audit_failures, format!("moe n={n}"), out.audit);
        scenarios.push(ScenarioPoint {
            name: "moe",
            nodes: n,
            duration_s: out.jobs[0].duration,
            mean_collective_s: out.jobs[0].mean_ar,
            collectives: out.jobs[0].ar_count,
        });

        // scenario 2 — inference weight broadcast: one source replicates
        // a weight shard to every replica over the spine, planner-routed
        // (the switch-multicast path when the fabric can replicate)
        let bc_w = Workload {
            layers: 1,
            hidden: cfg.hidden,
            batch_per_node: 64,
        };
        let bc = ClusterSpec::new(sys, n).with_topology(spine).with_job(
            JobSpec::new("wbcast", SystemKind::SmartNic { bfp: false }, bc_w, spine.contiguous_ranks(n))
                .with_layer_algos(vec![CollectiveAlgo::Auto])
                .with_layer_kinds(vec![CollectiveKind::Broadcast]),
        );
        let out = run_scenario_on(&bc, cfg.engine);
        fold_audit(
            &mut audit_clean,
            &mut audit_failures,
            format!("weight-broadcast n={n}"),
            out.audit,
        );
        scenarios.push(ScenarioPoint {
            name: "weight-broadcast",
            nodes: n,
            duration_s: out.jobs[0].duration,
            mean_collective_s: out.jobs[0].mean_ar,
            collectives: out.jobs[0].ar_count,
        });
    }
    CollectivesStudy {
        points,
        scenarios,
        audit_clean,
        audit_failures,
    }
}

/// Worst closed-form deviation over the gated cells at the pinned node
/// counts — the CLI's parity gate (and the acceptance criterion's 5%).
/// `None` when no gated pinned cell was swept: the gate then has nothing
/// to say and must not report a vacuous PASS.
pub fn worst_gated_parity(points: &[CollectivePoint]) -> Option<f64> {
    points
        .iter()
        .filter(|p| p.gated && PINNED_NODES.contains(&p.nodes))
        .map(|p| rel_err(p.model_s, p.measured_s))
        .fold(None, |acc: Option<f64>, e| Some(acc.map_or(e, |a| a.max(e))))
}

/// Worst all-to-all deviation over the spine — reported, never gated
/// (the fluid bound under-prices FIFO uplink convergence).
pub fn worst_alltoall_spine_err(points: &[CollectivePoint]) -> Option<f64> {
    points
        .iter()
        .filter(|p| p.kind == "all-to-all" && p.topology == "leaf-spine")
        .map(|p| rel_err(p.model_s, p.measured_s))
        .fold(None, |acc: Option<f64>, e| Some(acc.map_or(e, |a| a.max(e))))
}

/// Does measured switch multicast beat the measured binomial tree for
/// every leaf–spine broadcast at N ≥ 32?  `None` when no such pair was
/// swept (gate must not pass vacuously).
pub fn mcast_beats_binomial(points: &[CollectivePoint]) -> Option<bool> {
    let cell = |n: usize, plan: &str| {
        points
            .iter()
            .find(|p| {
                p.kind == "broadcast"
                    && p.topology == "leaf-spine"
                    && p.nodes == n
                    && p.plan == plan
            })
            .map(|p| p.measured_s)
    };
    let mut verdict = None;
    for n in points
        .iter()
        .filter(|p| p.kind == "broadcast" && p.topology == "leaf-spine" && p.nodes >= 32)
        .map(|p| p.nodes)
    {
        if let (Some(mc), Some(tree)) = (cell(n, "switch-multicast"), cell(n, "binomial")) {
            verdict = Some(verdict.unwrap_or(true) && mc < tree);
        }
    }
    verdict
}

pub fn print(study: &CollectivesStudy, cfg: &CollectivesConfig) {
    let mut t = Table::new(&[
        "kind",
        "nodes",
        "topology",
        "plan",
        "model (ms)",
        "engine (ms)",
        "err",
        "auto",
        "gate",
    ])
    .with_title(&format!(
        "collective zoo — planned collectives vs closed forms, {}:1 oversubscribed spine",
        cfg.oversubscription
    ));
    for p in &study.points {
        t.row(&[
            p.kind.to_string(),
            p.nodes.to_string(),
            p.topology.to_string(),
            p.plan.to_string(),
            fnum(p.model_s * 1e3, 3),
            fnum(p.measured_s * 1e3, 3),
            format!("{:.1}%", rel_err(p.model_s, p.measured_s) * 100.0),
            if p.chosen { "*".to_string() } else { String::new() },
            if p.gated { "hard".to_string() } else { "warn".to_string() },
        ]);
    }
    t.print();
    let mut s = Table::new(&["scenario", "nodes", "duration (ms)", "mean coll (ms)", "collectives"])
        .with_title("workload scenarios (planner-routed)");
    for p in &study.scenarios {
        s.row(&[
            p.name.to_string(),
            p.nodes.to_string(),
            fnum(p.duration_s * 1e3, 3),
            fnum(p.mean_collective_s * 1e3, 3),
            p.collectives.to_string(),
        ]);
    }
    s.print();
    match worst_gated_parity(&study.points) {
        Some(worst) => println!(
            "closed form vs engine on gated cells at N in {:?}: worst {:.1}% — {}",
            PINNED_NODES,
            worst * 100.0,
            if worst < PARITY_TOL { "PASS" } else { "FAIL" }
        ),
        None => println!(
            "closed form vs engine: not validated (no gated pinned N in {:?} swept)",
            PINNED_NODES
        ),
    }
    if let Some(worst) = worst_alltoall_spine_err(&study.points) {
        println!(
            "all-to-all over the spine: {:.1}% off the fluid bound (reported, not gated)",
            worst * 100.0
        );
    }
    match mcast_beats_binomial(&study.points) {
        Some(ok) => println!(
            "switch multicast vs binomial broadcast at N >= 32 on the spine: {}",
            if ok { "multicast wins — PASS" } else { "binomial wins somewhere — FAIL" }
        ),
        None => println!("switch multicast vs binomial: not compared (no N >= 32 swept)"),
    }
    match study.audit_clean {
        Some(true) => println!("invariant audit: clean on every run"),
        Some(false) => {
            println!("invariant audit: FAILED");
            for f in &study.audit_failures {
                println!("  {f}");
            }
        }
        None => {}
    }
}

/// Did every gate that had data pass?
pub fn gates_pass(study: &CollectivesStudy) -> bool {
    let parity_ok = worst_gated_parity(&study.points).is_some_and(|w| w < PARITY_TOL);
    let mcast_ok = mcast_beats_binomial(&study.points).unwrap_or(true);
    let audit_ok = study.audit_clean.unwrap_or(true);
    parity_ok && mcast_ok && audit_ok
}

/// Serialize the study to the `BENCH_collectives.json` schema.
pub fn to_json(cfg: &CollectivesConfig, study: &CollectivesStudy) -> Json {
    Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("oversubscription", Json::Num(cfg.oversubscription)),
                ("hidden", Json::Num(cfg.hidden as f64)),
                ("parity_tol", Json::Num(PARITY_TOL)),
            ]),
        ),
        (
            "points",
            Json::Arr(
                study
                    .points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("kind", Json::Str(p.kind.to_string())),
                            ("nodes", Json::Num(p.nodes as f64)),
                            ("topology", Json::Str(p.topology.to_string())),
                            ("plan", Json::Str(p.plan.to_string())),
                            ("model_s", Json::Num(p.model_s)),
                            ("measured_s", Json::Num(p.measured_s)),
                            ("parity_err", Json::Num(rel_err(p.model_s, p.measured_s))),
                            ("chosen", Json::Bool(p.chosen)),
                            ("gated", Json::Bool(p.gated)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "scenarios",
            Json::Arr(
                study
                    .scenarios
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("name", Json::Str(p.name.to_string())),
                            ("nodes", Json::Num(p.nodes as f64)),
                            ("duration_s", Json::Num(p.duration_s)),
                            ("mean_collective_s", Json::Num(p.mean_collective_s)),
                            ("collectives", Json::Num(p.collectives as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "gates",
            Json::obj(vec![
                (
                    "worst_gated_parity",
                    match worst_gated_parity(&study.points) {
                        Some(e) => Json::Num(e),
                        None => Json::Null,
                    },
                ),
                (
                    "worst_alltoall_spine_err",
                    match worst_alltoall_spine_err(&study.points) {
                        Some(e) => Json::Num(e),
                        None => Json::Null,
                    },
                ),
                (
                    "mcast_beats_binomial",
                    match mcast_beats_binomial(&study.points) {
                        Some(b) => Json::Bool(b),
                        None => Json::Null,
                    },
                ),
                (
                    "audit_clean",
                    match study.audit_clean {
                        Some(b) => Json::Bool(b),
                        None => Json::Null,
                    },
                ),
            ]),
        ),
    ])
}

/// Write the study to `path` (repo convention: `BENCH_collectives.json`,
/// uploaded as a CI artifact).
pub fn write_bench(
    path: &str,
    cfg: &CollectivesConfig,
    study: &CollectivesStudy,
) -> std::io::Result<()> {
    std::fs::write(path, to_json(cfg, study).to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> CollectivesConfig {
        CollectivesConfig {
            nodes: vec![6],
            ..CollectivesConfig::default()
        }
    }

    #[test]
    fn six_node_sweep_covers_every_kind_and_passes_parity() {
        let cfg = small_cfg();
        let study = run(&cfg);
        for kind in KINDS {
            for topo in ["flat", "leaf-spine"] {
                assert!(
                    study.points.iter().any(|p| p.kind == kind.name() && p.topology == topo),
                    "missing cell {} on {topo}",
                    kind.name()
                );
            }
        }
        // broadcast prices both the tree and the switch offload
        assert!(study
            .points
            .iter()
            .any(|p| p.kind == "broadcast" && p.plan == "switch-multicast"));
        let worst = worst_gated_parity(&study.points).expect("6 is a pinned node count");
        assert!(worst < PARITY_TOL, "gated parity err {:.1}%", worst * 100.0);
        assert!(study.audit_clean.is_none(), "typed engine runs unaudited");
        // every cell got exactly one auto choice
        for kind in KINDS {
            let chosen = study
                .points
                .iter()
                .filter(|p| p.kind == kind.name() && p.topology == "leaf-spine" && p.chosen)
                .count();
            assert_eq!(chosen, 1, "{} needs exactly one chosen plan", kind.name());
        }
    }

    #[test]
    fn parity_gate_refuses_to_pass_vacuously() {
        let point = CollectivePoint {
            kind: "broadcast",
            nodes: 64, // not a pinned node count
            topology: "flat",
            plan: "binomial",
            model_s: 2.0,
            measured_s: 1.0, // 100% off — and still not a PASS signal
            chosen: true,
            gated: true,
        };
        assert!(worst_gated_parity(&[point.clone()]).is_none());
        assert!(mcast_beats_binomial(&[point]).is_none());
    }

    #[test]
    fn moe_and_broadcast_scenarios_complete() {
        let cfg = small_cfg();
        let study = run(&cfg);
        let moe = study
            .scenarios
            .iter()
            .find(|s| s.name == "moe")
            .expect("moe scenario");
        assert_eq!(moe.collectives, 2);
        assert!(moe.duration_s > 0.0 && moe.duration_s.is_finite());
        let bc = study
            .scenarios
            .iter()
            .find(|s| s.name == "weight-broadcast")
            .expect("broadcast scenario");
        assert_eq!(bc.collectives, 1);
        assert!(bc.mean_collective_s > 0.0);
    }

    #[test]
    fn audited_run_is_clean() {
        let cfg = CollectivesConfig {
            nodes: vec![6],
            engine: EngineKind::Checked { threads: 0 },
            ..CollectivesConfig::default()
        };
        let study = run(&cfg);
        assert_eq!(study.audit_clean, Some(true), "{:?}", study.audit_failures);
    }

    #[test]
    fn bench_json_round_trips() {
        let cfg = small_cfg();
        let study = run(&cfg);
        let j = to_json(&cfg, &study);
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed, j);
        let first = j.get("points").unwrap().idx(0).unwrap();
        assert_eq!(first.get("nodes").unwrap().as_usize(), Some(6));
        assert!(first.get("measured_s").unwrap().as_f64().unwrap() > 0.0);
        // gates are present and non-vacuous for a pinned sweep
        let gates = j.get("gates").unwrap();
        assert!(gates.get("worst_gated_parity").unwrap().as_f64().is_some());
        assert_eq!(gates.get("audit_clean").unwrap(), &Json::Null);
    }
}
