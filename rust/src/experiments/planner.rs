//! E8 — planner study: NIC-side vs switch-side collective offload on a
//! tapered leaf–spine fabric.
//!
//! For every node count (racked 8-per-leaf when the count allows, 2
//! leaves otherwise) and both placements, one paper-sized all-reduce runs
//! on the unified engine under four algorithms — the flat NIC ring, the
//! planner's hierarchical plan, NetReduce-style in-switch reduction, and
//! `Auto` (the planner's own choice) — next to the closed forms of
//! `analytic::model`.  The study answers the two questions PR 2 left
//! open: how much of the strided-ring oversubscription penalty a
//! placement-aware plan recovers, and where switch-resident reduction
//! overtakes the smart NIC.
//!
//! `smartnic plan` prints the table and writes `BENCH_planner.json`; the
//! run fails (nonzero exit) if the hierarchical plan does not beat the
//! strided NIC ring, or the in-switch closed form drifts from the engine
//! by ≥ 5% at the pinned node counts.

use crate::analytic::model::{
    hierarchical_ar_time_elems, inswitch_ar_time_elems, nic_ring_ar_time_elems, SystemKind,
};
use crate::cluster::planner::{plan, ring_uplink_factor};
use crate::cluster::{run_scenario, ClusterSpec, CollectiveAlgo, JobSpec, Topology};
use crate::sysconfig::{SwitchParams, SystemParams, Workload};
use crate::util::json::Json;
use crate::util::stats::rel_err;
use crate::util::table::{fnum, Table};

/// Algorithms compared at every point, in column order.
pub const ALGOS: [&str; 4] = ["nic-ring", "hierarchical", "in-switch", "auto"];

/// Node counts whose in-switch closed form is pinned to the engine.
pub const PINNED_NODES: [usize; 3] = [6, 32, 128];

/// Tolerance of the in-switch closed form vs the unified engine.
pub const INSWITCH_TOL: f64 = 0.05;

/// Sweep parameters.
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    /// node counts (even, ≥ 4: racked 8-per-leaf when divisible, else 2
    /// leaves)
    pub nodes: Vec<usize>,
    /// leaf uplink oversubscription factor
    pub oversubscription: f64,
    /// gradient width: hidden² elements per all-reduce
    pub hidden: usize,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        Self {
            nodes: vec![6, 12, 32, 64, 128, 512],
            oversubscription: 4.0,
            hidden: 2048,
        }
    }
}

/// Leaf shape for a node count: racks of 8 when the count divides into at
/// least two of them, otherwise two leaves.
pub fn leaf_shape(n: usize) -> (usize, usize) {
    if n % 8 == 0 && n / 8 >= 2 {
        (n / 8, 8)
    } else {
        (2, n / 2)
    }
}

/// One (node count, placement) cell of the study.
#[derive(Clone, Debug)]
pub struct PlannerPoint {
    pub nodes: usize,
    pub leaves: usize,
    pub placement: &'static str,
    /// measured mean AR latency (s) per algorithm ([`ALGOS`] order)
    pub measured: [f64; 4],
    /// closed-form prediction per algorithm (auto = its chosen plan's)
    pub model: [f64; 4],
    /// plan family `Auto` selected
    pub chosen: &'static str,
}

impl PlannerPoint {
    /// Strided-penalty recovery: ring AR time over the chosen plan's.
    pub fn speedup_over_ring(&self, algo_idx: usize) -> f64 {
        self.measured[0] / self.measured[algo_idx]
    }
}

/// The smart-NIC system with a NetReduce-provisioned switch tier: every
/// engine keeps line rate for its switch's full radix.
pub fn planner_system(leaves: usize, nodes_per_leaf: usize) -> SystemParams {
    let base = SystemParams::smartnic_40g();
    base.with_switch_reduction(SwitchParams::netreduce(nodes_per_leaf.max(leaves), &base.net))
}

/// Mean AR latency of one `hidden`²-element collective under `algo` on
/// the unified engine — the single measurement protocol shared by the
/// benchmark, the property tests and the planner example.
pub fn measure_ar(
    sys: SystemParams,
    topo: Topology,
    ranks: Vec<usize>,
    algo: CollectiveAlgo,
    hidden: usize,
) -> f64 {
    let w = Workload {
        layers: 1,
        hidden,
        batch_per_node: 64,
    };
    let spec = ClusterSpec::new(sys, topo.nodes())
        .with_topology(topo)
        .with_job(
            JobSpec::new("ar", SystemKind::SmartNic { bfp: false }, w, ranks)
                .with_layer_algos(vec![algo]),
        );
    run_scenario(&spec).jobs[0].mean_ar
}

/// Run the full study.
pub fn run(cfg: &PlannerConfig) -> Vec<PlannerPoint> {
    let elems = cfg.hidden * cfg.hidden;
    let mut out = Vec::new();
    for &n in &cfg.nodes {
        assert!(n >= 4 && n % 2 == 0, "planner sweep needs even node counts >= 4, got {n}");
        let (leaves, m) = leaf_shape(n);
        let sys = planner_system(leaves, m);
        let topo = Topology::leaf_spine(leaves, m, cfg.oversubscription);
        for (placement, ranks) in [
            ("contiguous", topo.contiguous_ranks(n)),
            ("strided", topo.strided_ranks(n)),
        ] {
            let algos = [
                CollectiveAlgo::NicRing,
                CollectiveAlgo::NicHierarchical,
                CollectiveAlgo::SwitchReduce,
                CollectiveAlgo::Auto,
            ];
            let mut measured = [0.0f64; 4];
            for (i, algo) in algos.into_iter().enumerate() {
                measured[i] = measure_ar(sys, topo, ranks.clone(), algo, cfg.hidden);
            }
            let auto_plan = plan(&sys, &topo, &ranks, elems, 1.0);
            let model = [
                nic_ring_ar_time_elems(&sys, elems, n, 1.0, ring_uplink_factor(&topo, &ranks)),
                hierarchical_ar_time_elems(&sys, elems, m, leaves, cfg.oversubscription, 1.0),
                inswitch_ar_time_elems(&sys, elems, m, leaves, cfg.oversubscription, 1.0),
                auto_plan.predicted,
            ];
            out.push(PlannerPoint {
                nodes: n,
                leaves,
                placement,
                measured,
                model,
                chosen: auto_plan.kind.name(),
            });
        }
    }
    out
}

/// Worst in-switch closed-form deviation at the pinned node counts — the
/// CLI gate (and the acceptance criterion's 5%).  `None` when the sweep
/// contains no pinned node count: the gate then has nothing to say and
/// must not report a vacuous PASS.
pub fn worst_inswitch_err(points: &[PlannerPoint]) -> Option<f64> {
    points
        .iter()
        .filter(|p| PINNED_NODES.contains(&p.nodes))
        .map(|p| rel_err(p.model[2], p.measured[2]))
        .fold(None, |acc: Option<f64>, e| Some(acc.map_or(e, |a| a.max(e))))
}

/// Does the hierarchical plan beat the flat NIC ring on every strided
/// point (the tentpole's reason to exist)?
pub fn hierarchical_beats_strided_ring(points: &[PlannerPoint]) -> bool {
    points
        .iter()
        .filter(|p| p.placement == "strided")
        .all(|p| p.measured[1] < p.measured[0])
}

pub fn print(points: &[PlannerPoint], cfg: &PlannerConfig) {
    let mut t = Table::new(&[
        "nodes",
        "shape",
        "placement",
        "ring m/u (ms)",
        "hier m/u (ms)",
        "switch m/u (ms)",
        "auto (ms)",
        "chosen",
        "best vs ring",
    ])
    .with_title(&format!(
        "planner study — NIC ring vs hierarchical vs in-switch, {}:1 oversubscribed leaf-spine",
        cfg.oversubscription
    ));
    for p in points {
        let pair = |i: usize| {
            format!("{} / {}", fnum(p.model[i] * 1e3, 2), fnum(p.measured[i] * 1e3, 2))
        };
        let best = p.measured[1].min(p.measured[2]).min(p.measured[3]);
        t.row(&[
            p.nodes.to_string(),
            format!("{}x{}", p.leaves, p.nodes / p.leaves),
            p.placement.to_string(),
            pair(0),
            pair(1),
            pair(2),
            fnum(p.measured[3] * 1e3, 2),
            p.chosen.to_string(),
            format!("x{}", fnum(p.measured[0] / best, 2)),
        ]);
    }
    t.print();
    match worst_inswitch_err(points) {
        Some(worst) => println!(
            "in-switch closed form vs engine at N in {:?}: worst {:.1}% — {}",
            PINNED_NODES,
            worst * 100.0,
            if worst < INSWITCH_TOL { "PASS" } else { "FAIL" }
        ),
        None => println!(
            "in-switch closed form vs engine: not validated (no pinned N in {:?} swept)",
            PINNED_NODES
        ),
    }
    println!(
        "hierarchical vs strided NIC ring: {}",
        if hierarchical_beats_strided_ring(points) {
            "recovers the oversubscription penalty on every strided point — PASS"
        } else {
            "slower than the strided ring somewhere — FAIL"
        }
    );
}

/// Serialize the study to the `BENCH_planner.json` schema.
pub fn to_json(cfg: &PlannerConfig, points: &[PlannerPoint]) -> Json {
    Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("oversubscription", Json::Num(cfg.oversubscription)),
                ("hidden", Json::Num(cfg.hidden as f64)),
                ("inswitch_tol", Json::Num(INSWITCH_TOL)),
            ]),
        ),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        let per_algo = |vals: &[f64; 4]| {
                            Json::obj(
                                ALGOS
                                    .iter()
                                    .zip(vals)
                                    .map(|(name, v)| (*name, Json::Num(*v)))
                                    .collect(),
                            )
                        };
                        Json::obj(vec![
                            ("nodes", Json::Num(p.nodes as f64)),
                            ("leaves", Json::Num(p.leaves as f64)),
                            ("placement", Json::Str(p.placement.to_string())),
                            ("measured_s", per_algo(&p.measured)),
                            ("model_s", per_algo(&p.model)),
                            ("chosen", Json::Str(p.chosen.to_string())),
                            (
                                "speedup_vs_ring",
                                Json::obj(vec![
                                    ("hierarchical", Json::Num(p.speedup_over_ring(1))),
                                    ("in_switch", Json::Num(p.speedup_over_ring(2))),
                                    ("auto", Json::Num(p.speedup_over_ring(3))),
                                ]),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "gates",
            Json::obj(vec![
                (
                    "worst_inswitch_err",
                    match worst_inswitch_err(points) {
                        Some(e) => Json::Num(e),
                        None => Json::Null,
                    },
                ),
                (
                    "hierarchical_beats_strided_ring",
                    Json::Bool(hierarchical_beats_strided_ring(points)),
                ),
            ]),
        ),
    ])
}

/// Write the study to `path` (repo convention: `BENCH_planner.json`,
/// uploaded as a CI artifact).
pub fn write_bench(
    path: &str,
    cfg: &PlannerConfig,
    points: &[PlannerPoint],
) -> std::io::Result<()> {
    std::fs::write(path, to_json(cfg, points).to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> PlannerConfig {
        PlannerConfig {
            nodes: vec![6],
            ..PlannerConfig::default()
        }
    }

    #[test]
    fn inswitch_gate_refuses_to_pass_vacuously() {
        // a sweep with no pinned node count must report None, not 0.0
        let point = PlannerPoint {
            nodes: 64,
            leaves: 8,
            placement: "strided",
            measured: [1.0; 4],
            model: [2.0; 4], // 100% off — and still not a PASS signal
            chosen: "ring",
        };
        assert!(worst_inswitch_err(&[point]).is_none());
    }

    #[test]
    fn leaf_shapes() {
        assert_eq!(leaf_shape(6), (2, 3));
        assert_eq!(leaf_shape(12), (2, 6));
        assert_eq!(leaf_shape(32), (4, 8));
        assert_eq!(leaf_shape(512), (64, 8));
    }

    #[test]
    fn six_node_point_passes_both_gates() {
        let cfg = small_cfg();
        let pts = run(&cfg);
        assert_eq!(pts.len(), 2);
        assert!(hierarchical_beats_strided_ring(&pts));
        let worst = worst_inswitch_err(&pts).expect("6 is a pinned node count");
        assert!(worst < INSWITCH_TOL, "in-switch err {:.1}%", worst * 100.0);
        // auto never loses to any measured fixed algorithm (small slack
        // for model-vs-engine ordering noise near ties)
        for p in &pts {
            let best = p.measured[..3].iter().fold(f64::INFINITY, |a, &b| a.min(b));
            assert!(
                p.measured[3] <= best * 1.05,
                "{} {}: auto {} vs best {}",
                p.nodes,
                p.placement,
                p.measured[3],
                best
            );
        }
    }

    #[test]
    fn bench_json_round_trips() {
        let cfg = small_cfg();
        let pts = run(&cfg);
        let j = to_json(&cfg, &pts);
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed, j);
        let first = j.get("points").unwrap().idx(0).unwrap();
        assert_eq!(first.get("nodes").unwrap().as_usize(), Some(6));
        for algo in ALGOS {
            let v = first.get("measured_s").unwrap().get(algo).unwrap();
            assert!(v.as_f64().unwrap() > 0.0);
        }
    }
}
