// Golden-file cross-validation: the Rust BFP codec must reproduce the
// python reference (kernels/ref.py) bit for bit on the vectors emitted by
// the AOT pipeline (artifacts/golden/bfp_cases.json).

use ai_smartnic::bfp::BfpCodec;
use ai_smartnic::util::json::Json;

fn golden() -> Option<Json> {
    let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/golden/bfp_cases.json");
    let text = std::fs::read_to_string(p).ok()?;
    Some(Json::parse(&text).expect("golden json parses"))
}

#[test]
fn rust_codec_matches_python_golden_vectors() {
    let Some(g) = golden() else {
        eprintln!("skipping: no golden vectors (run `make artifacts`)");
        return;
    };
    let cases = g.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 8, "expected a rich golden set");
    for case in cases {
        let name = case.get("name").unwrap().as_str().unwrap();
        let bs = case.get("block_size").unwrap().as_usize().unwrap();
        let mb = case.get("mant_bits").unwrap().as_usize().unwrap() as u32;
        let codec = BfpCodec::new(bs, mb);
        let x: Vec<f32> = case
            .get("x_bits")
            .unwrap()
            .num_vec(|v| f32::from_bits(v as u32))
            .unwrap();
        let want_e: Vec<i64> = case.get("e_shared").unwrap().num_vec(|v| v as i64).unwrap();
        let want_sign: Vec<i64> = case.get("sign").unwrap().num_vec(|v| v as i64).unwrap();
        let want_mag: Vec<i64> = case.get("mag").unwrap().num_vec(|v| v as i64).unwrap();
        let want_dec: Vec<u32> = case
            .get("decoded_bits")
            .unwrap()
            .num_vec(|v| v as u32)
            .unwrap();

        let blocks = codec.encode(&x);
        assert_eq!(blocks.len(), want_e.len(), "{name}: block count");
        for (bi, blk) in blocks.iter().enumerate() {
            assert_eq!(blk.e_shared as i64, want_e[bi], "{name}: E of block {bi}");
            for i in 0..bs {
                let gi = bi * bs + i;
                assert_eq!(blk.sign[i] as i64, want_sign[gi], "{name}: sign[{gi}]");
                assert_eq!(blk.mag[i] as i64, want_mag[gi], "{name}: mag[{gi}]");
            }
        }
        let dec = codec.decode(&blocks, x.len());
        for (i, (d, wbits)) in dec.iter().zip(&want_dec).enumerate() {
            assert_eq!(
                d.to_bits(),
                *wbits,
                "{name}: decoded[{i}] {d} vs {}",
                f32::from_bits(*wbits)
            );
        }
        // and the one-shot quantize path agrees with encode+decode
        assert_eq!(codec.quantize(&x), dec, "{name}: quantize path");
    }
}
