// Integration tests for the hierarchical (leaf–spine) fabric and the
// fabric bugfixes that landed with it:
//  * property test: the pipelined ring schedule is contention-free on a
//    single leaf (and on the flat crossbar), exhibits measurable queueing
//    once it spans leaves under >1:1 oversubscription, and stays bounded
//    when the spine is non-blocking;
//  * determinism of the multi-hop routing;
//  * regression: a straggler's host comm cores really slow host-MPI jobs;
//  * regression: traffic *toward* a degraded node slows (egress-port
//    fault scaling), and the serialized/unified engines stay close;
//  * the β wire-protocol-efficiency factor is applied identically by the
//    closed form, the serialized NIC DES, the unified engine and the
//    host software model.

use ai_smartnic::analytic::model::SystemKind;
use ai_smartnic::analytic::validate::{smartnic_ar_time_elems, validate_ar};
use ai_smartnic::cluster::{run_scenario, ClusterSpec, JobSpec, Topology};
use ai_smartnic::collective::timing::{allreduce_time, HostNet};
use ai_smartnic::collective::Scheme;
use ai_smartnic::coordinator::{simulate_iteration, simulate_iteration_unified};
use ai_smartnic::netsim::fabric::Fabric;
use ai_smartnic::netsim::topology::Ring;
use ai_smartnic::nic::{simulate_ring_allreduce, NicConfig};
use ai_smartnic::prop::{forall, gens};
use ai_smartnic::sysconfig::{ClusterFaults, SystemParams, Workload};
use ai_smartnic::util::stats::rel_err;

/// Replay the pipelined ring schedule through a fabric, one barrier-
/// synchronized step at a time.  Returns the completion time and whether
/// every hop finished at exactly its uncontended ideal (Tx serialization
/// plus the route's switch latencies).
fn replay_ring(topo: Topology, ranks: &[usize], chunk: f64) -> (f64, bool) {
    let sys = SystemParams::smartnic_40g();
    let mut fab = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
    let bw = sys.net.effective_bw();
    let lat = sys.net.hop_latency;
    let n = ranks.len();
    let ring = Ring::new(n);
    let mut t_step = 0.0f64;
    let mut contention_free = true;
    for _step in 0..ring.allreduce_steps() {
        let mut max_done = t_step;
        for i in 0..n {
            let (src, dst) = (ranks[i], ranks[ring.next(i)]);
            let done = fab.hop(src, dst, t_step, chunk);
            let ideal = t_step + chunk / bw + topo.hops(src, dst) as f64 * lat;
            if (done - ideal).abs() > 1e-12 {
                contention_free = false;
            }
            max_done = max_done.max(done);
        }
        t_step = max_done;
    }
    (t_step, contention_free)
}

#[test]
fn prop_ring_contention_freedom_depends_on_placement_and_oversubscription() {
    let chunk = 1e6;
    forall(
        &gens::pair(gens::usize_in(2..=4), gens::usize_in(2..=5)),
        25,
        |&(leaves, m)| {
            let n = leaves * m;
            let tapered = Topology::leaf_spine(leaves, m, 4.0);
            let non_blocking = Topology::leaf_spine(leaves, m, 1.0);
            let crossbar = Topology::flat(n);
            let flat = replay_ring(crossbar, &crossbar.contiguous_ranks(n), chunk);
            // a ring confined to one leaf is exactly contention-free,
            // 4:1 tapering or not — the uplinks are never touched
            let one_leaf = replay_ring(tapered, &tapered.contiguous_ranks(m), chunk);
            // strided across leaves, every edge crosses the 4:1 spine:
            // the schedule queues on the uplink bundles
            let spanning = replay_ring(tapered, &tapered.strided_ranks(n), chunk);
            // same placement over a non-blocking spine: only a bounded
            // transient, no sustained queueing blow-up
            let nb = replay_ring(non_blocking, &non_blocking.strided_ranks(n), chunk);
            flat.1
                && one_leaf.1
                && !spanning.1
                && spanning.0 > 2.0 * flat.0
                && nb.0 < 2.05 * flat.0
        },
    );
}

fn leaf_spine_two_job_spec() -> ClusterSpec {
    let sys = SystemParams::smartnic_40g();
    let topo = Topology::leaf_spine(3, 4, 2.0);
    let w = Workload {
        layers: 6,
        hidden: 1024,
        batch_per_node: 128,
    };
    ClusterSpec::new(sys, 12)
        .with_topology(topo)
        .with_job(JobSpec::new(
            "strided",
            SystemKind::SmartNic { bfp: false },
            w,
            topo.strided_ranks(12),
        ))
        .with_job(JobSpec::new(
            "contig",
            SystemKind::SmartNic { bfp: true },
            w,
            topo.contiguous_ranks(12),
        ))
}

#[test]
fn multi_hop_routing_is_deterministic() {
    let a = run_scenario(&leaf_spine_two_job_spec());
    let b = run_scenario(&leaf_spine_two_job_spec());
    assert_eq!(a.events, b.events);
    assert_eq!(a.trace.spans, b.trace.spans);
    for (ja, jb) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(ja.t_end, jb.t_end);
        assert_eq!(ja.mean_ar, jb.mean_ar);
    }
    // and the strided tenant is the one paying the spine tax
    assert!(a.jobs[0].mean_ar > a.jobs[1].mean_ar);
}

#[test]
fn straggler_slows_host_mpi_comm_cores() {
    // regression (Fabric::new used to hard-code Server::new(1.0) for the
    // comm cores): a straggling node's software all-reduce rounds must
    // drain slower, gating every barrier of a host-MPI job
    let sys = SystemParams::baseline_100g();
    let w = Workload {
        layers: 4,
        hidden: 2048,
        batch_per_node: 64,
    };
    let kind = SystemKind::BaselineNaive {
        scheme: Scheme::Ring,
    };
    let healthy_out = run_scenario(
        &ClusterSpec::new(sys, 4).with_job(JobSpec::new("h", kind, w, (0..4).collect())),
    );
    let slowed_out = run_scenario(
        &ClusterSpec::new(sys, 4)
            .with_faults(ClusterFaults::none().with_straggler(2, 0.25))
            .with_job(JobSpec::new("s", kind, w, (0..4).collect())),
    );
    let (healthy, slowed) = (healthy_out.jobs[0].duration, slowed_out.jobs[0].duration);
    assert!(
        slowed > healthy * 1.5,
        "straggler ignored by host path: {slowed} vs {healthy}"
    );
}

#[test]
fn degraded_link_slows_traffic_toward_the_victim() {
    // regression: with_degraded_link used to scale only the victim's Tx
    // uplink; incast toward the victim was unaffected.  Route the same
    // incast through a faulty and a healthy fabric and compare.
    let sys = SystemParams::smartnic_40g();
    let faults = ClusterFaults::none().with_degraded_link(3, 0.25);
    let mut faulty = Fabric::new(&sys, 6, &faults);
    let mut healthy = Fabric::new(&sys, 6, &ClusterFaults::none());
    let bytes = 4e6;
    let last_faulty = (0..3).map(|s| faulty.hop(s, 3, 0.0, bytes)).fold(0.0, f64::max);
    let last_healthy = (0..3).map(|s| healthy.hop(s, 3, 0.0, bytes)).fold(0.0, f64::max);
    assert!(
        last_faulty > last_healthy * 2.0,
        "incast unaffected by degraded egress: {last_faulty} vs {last_healthy}"
    );
}

#[test]
fn beta_wire_efficiency_consistent_across_all_paths() {
    // pin the β factor (satellite of the α·BW_eth·β reconciliation):
    // every timing path must derate the wire identically.
    let mut sys = SystemParams::smartnic_40g();
    sys.net = sys.net.with_beta(0.9);

    // 1) serialized NIC DES == unified engine, exactly, for a single ring
    let hidden = 1024;
    let serialized = simulate_ring_allreduce(&NicConfig::new(sys, None), 6, hidden * hidden)
        .t_total;
    let w = Workload {
        layers: 1,
        hidden,
        batch_per_node: 64,
    };
    let spec = ClusterSpec::new(sys, 6).with_job(JobSpec::new(
        "ring",
        SystemKind::SmartNic { bfp: false },
        w,
        (0..6).collect(),
    ));
    let unified = run_scenario(&spec).jobs[0].mean_ar;
    assert!(
        (serialized - unified).abs() / serialized < 1e-9,
        "beta applied asymmetrically: serialized {serialized} unified {unified}"
    );

    // 2) closed form vs serialized DES at the paper's layer size
    let v = validate_ar(&sys, 6, 2048 * 2048, false);
    assert!(
        v.rel_err < 0.03,
        "closed form diverges under beta: {:.1}%",
        v.rel_err * 100.0
    );

    // 3) full-iteration parity at the paper's operating point
    let wl = Workload::paper_mlp(1792);
    let kind = SystemKind::SmartNic { bfp: false };
    let ser_iter = simulate_iteration(kind, &sys, &wl, 6).breakdown.t_total;
    let uni_iter = simulate_iteration_unified(kind, &sys, &wl, 6)
        .breakdown
        .t_total;
    let err = rel_err(ser_iter, uni_iter);
    assert!(err < 0.03, "iteration parity under beta: {:.2}%", err * 100.0);

    // 4) the closed form actually slows down by 1/beta where the ring
    // term dominates
    let base = SystemParams::smartnic_40g();
    let t_raw = smartnic_ar_time_elems(&base, 4 * 1024 * 1024, 6, false);
    let t_derated = smartnic_ar_time_elems(&sys, 4 * 1024 * 1024, 6, false);
    assert!(
        t_derated > t_raw * 1.05,
        "beta ignored by the closed form: {t_derated} vs {t_raw}"
    );

    // 5) the host software model derates the wire the same way (with the
    // comm-core cap lifted so the wire is the binding constraint)
    let mk_env = |beta: f64| HostNet {
        net: SystemParams::baseline_100g().net.with_beta(beta),
        step_overhead: 0.0,
        comm_bw_cap: f64::INFINITY,
    };
    let bytes = 512.0 * 1024.0 * 1024.0;
    let full = allreduce_time(Scheme::Ring, 8, bytes, &mk_env(1.0));
    let half = allreduce_time(Scheme::Ring, 8, bytes, &mk_env(0.5));
    // bandwidth term doubles; the fixed per-step hop latencies don't
    assert!(
        (half / full - 2.0).abs() < 0.01,
        "host model beta scaling: {half} vs {full}"
    );
}
