// Integration: PJRT runtime over the AOT artifacts (requires
// `make artifacts` to have run — skipped otherwise).

use ai_smartnic::runtime::{Engine, Tensor};
use ai_smartnic::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn host_matmul(x: &[f32], w: &[f32], b: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0f32; b * m];
    for r in 0..b {
        for c in 0..m {
            let mut acc = 0f32;
            for k in 0..m {
                acc += x[r * m + k] * w[k * m + c];
            }
            out[r * m + c] = acc;
        }
    }
    out
}

#[test]
fn engine_loads_every_artifact() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::open(&dir).unwrap();
    assert!(engine.manifest.artifacts.len() >= 9);
    // compile them all — any HLO-text incompatibility shows up here
    for a in engine.manifest.artifacts.clone() {
        engine.warmup(&a.name).unwrap();
    }
}

#[test]
fn layer_fwd_matches_host_reference() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::open(&dir).unwrap();
    let (m, b) = (64usize, 16usize);
    let mut rng = Rng::new(7);
    let x = Tensor::randn(&[b, m], 1.0, &mut rng);
    let w = Tensor::randn(&[m, m], 0.2, &mut rng);
    let bias = Tensor::randn(&[m], 0.1, &mut rng);
    let out = engine
        .run(&format!("layer_fwd_m{m}_b{b}"), &[&x, &w, &bias])
        .unwrap();
    assert_eq!(out.len(), 2);
    let z_ref: Vec<f32> = host_matmul(&x.data, &w.data, b, m)
        .iter()
        .enumerate()
        .map(|(i, v)| v + bias.data[i % m])
        .collect();
    let y_ref: Vec<f32> = z_ref.iter().map(|&v| v.max(0.0)).collect();
    for (got, want) in out[1].data.iter().zip(&z_ref) {
        assert!((got - want).abs() < 1e-3, "z: {got} vs {want}");
    }
    for (got, want) in out[0].data.iter().zip(&y_ref) {
        assert!((got - want).abs() < 1e-3, "y: {got} vs {want}");
    }
}

#[test]
fn sgd_update_works() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::open(&dir).unwrap();
    let m = 64usize;
    let w = Tensor::new(vec![m, m], vec![1.0; m * m]);
    let dw = Tensor::new(vec![m, m], vec![2.0; m * m]);
    let lr = Tensor::scalar(0.25);
    let out = engine
        .run(&format!("sgd_update_m{m}"), &[&w, &dw, &lr])
        .unwrap();
    assert!(out[0].data.iter().all(|&v| (v - 0.5).abs() < 1e-6));
}

#[test]
fn shape_mismatch_is_rejected() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::open(&dir).unwrap();
    let bad = Tensor::zeros(&[3, 3]);
    let err = engine
        .run("layer_fwd_m64_b16", &[&bad, &bad, &bad])
        .unwrap_err();
    assert!(err.to_string().contains("shape"), "{err}");
}

#[test]
fn bfp_roundtrip_artifact_matches_rust_codec() {
    // the Pallas BFP kernel (inside the artifact) and the Rust codec must
    // quantize identically — the cross-layer contract, checked through the
    // full PJRT path
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::open(&dir).unwrap();
    let m = 64usize;
    let mut rng = Rng::new(11);
    let g = Tensor::randn(&[m, m], 1.0, &mut rng);
    let out = engine.run(&format!("bfp_roundtrip_m{m}"), &[&g]).unwrap();
    let rust_q = ai_smartnic::bfp::BfpCodec::bfp16().quantize(&g.data);
    assert_eq!(out[0].data, rust_q, "pallas-vs-rust BFP mismatch");
}
