// Cross-engine equivalence suite: the typed-event calendar engine
// (`EngineKind::Typed`) must reproduce the boxed-closure baseline
// (`EngineKind::BoxedBaseline` — the PR-3 representation) bit-for-bit.
// Both backends execute the identical `(time, seq)` event order, so
// every virtual-time result must agree within 1e-9 (the observed
// deviation is exactly zero) for every plan family at N in {6, 32, 128},
// under concurrency, ties, multi-tenancy and fault injection — and the
// engine-behavior contracts (determinism under ties, schedule-into-past
// panics) must survive the representation change.
//
// The leaf-partitioned parallel executive (`EngineKind::Parallel`) is
// held to the same bar against the sequential typed engine: every plan
// family at N in {128, 2048} and threads in {1, 2, 4} must agree within
// 1e-9, the multi-tenant faulty scenario included, and results must be
// bit-identical across thread counts (ties are resolved by partition
// index at the window barrier, never by scheduling races).
//
// The checked executive (`EngineKind::Checked` — the invariant auditor
// of docs/INVARIANTS.md) is held to the same bar again: auditing must
// not perturb execution (same matrix, same 1e-9, bit-identical across
// audited thread counts), every dispatch must be checked, and every
// report must come back clean — the conservation ledger included.

use ai_smartnic::analytic::model::SystemKind;
use ai_smartnic::cluster::{
    run_scenario_on, run_trace, synth_trace, ClusterSpec, CollectiveAlgo, CollectiveKind,
    EngineKind, JobSpec, Policy, ScenarioOutput, Topology, TraceGenConfig, TraceOutput, TraceSpec,
};
use ai_smartnic::collective::Scheme;
use ai_smartnic::coordinator::simulate_iteration_unified_on;
use ai_smartnic::experiments::planner::{leaf_shape, planner_system};
use ai_smartnic::netsim::engine::{Sim, World};
use ai_smartnic::sysconfig::{ClusterFaults, PfcParams, SystemParams, Workload};
use ai_smartnic::util::stats::{percentile, rel_err};

/// Node counts every plan family is pinned at.
const PINNED: [usize; 3] = [6, 32, 128];
/// Virtual-time agreement required between the two representations.
const TOL: f64 = 1e-9;

/// Small-but-nontrivial gradient width per node count (keeps the debug
/// build fast while still pipelining multiple ring steps per rank).
fn hidden_for(n: usize) -> usize {
    if n >= 128 {
        256
    } else {
        512
    }
}

fn run_both(spec: &ClusterSpec) -> (ScenarioOutput, ScenarioOutput) {
    (
        run_scenario_on(spec, EngineKind::Typed),
        run_scenario_on(spec, EngineKind::BoxedBaseline),
    )
}

fn assert_equiv(spec: &ClusterSpec, label: &str) {
    let (typed, boxed) = run_both(spec);
    assert_eq!(typed.events, boxed.events, "{label}: event counts diverged");
    assert_eq!(typed.jobs.len(), boxed.jobs.len(), "{label}");
    for (t, b) in typed.jobs.iter().zip(&boxed.jobs) {
        assert_eq!(t.ar_count, b.ar_count, "{label}/{}", t.name);
        assert!(
            rel_err(b.duration, t.duration) <= TOL,
            "{label}/{}: typed {} vs boxed {}",
            t.name,
            t.duration,
            b.duration
        );
        assert!(
            rel_err(b.mean_ar, t.mean_ar) <= TOL,
            "{label}/{}: mean AR typed {} vs boxed {}",
            t.name,
            t.mean_ar,
            b.mean_ar
        );
    }
    assert!(
        rel_err(boxed.makespan, typed.makespan) <= TOL,
        "{label}: makespan typed {} vs boxed {}",
        typed.makespan,
        boxed.makespan
    );
}

/// One single-job spec on the planner study's provisioned leaf–spine
/// fabric (the shape every plan family can run on).
fn family_spec(n: usize, algo: CollectiveAlgo) -> ClusterSpec {
    let (leaves, m) = leaf_shape(n);
    let sys = planner_system(leaves, m);
    let topo = Topology::leaf_spine(leaves, m, 4.0);
    let w = Workload {
        layers: 2,
        hidden: hidden_for(n),
        batch_per_node: 64,
    };
    ClusterSpec::new(sys, n).with_topology(topo).with_job(
        JobSpec::new("j0", SystemKind::SmartNic { bfp: false }, w, topo.contiguous_ranks(n))
            .with_layer_algos(vec![algo; 2]),
    )
}

#[test]
fn ring_matches_boxed_engine_at_pinned_sizes() {
    for n in PINNED {
        assert_equiv(&family_spec(n, CollectiveAlgo::NicRing), &format!("ring/n={n}"));
    }
}

#[test]
fn binomial_matches_boxed_engine_at_pinned_sizes() {
    for n in PINNED {
        assert_equiv(&family_spec(n, CollectiveAlgo::NicBinomial), &format!("binomial/n={n}"));
    }
}

#[test]
fn rabenseifner_matches_boxed_engine_at_pinned_sizes() {
    for n in PINNED {
        assert_equiv(
            &family_spec(n, CollectiveAlgo::NicRabenseifner),
            &format!("rabenseifner/n={n}"),
        );
    }
}

#[test]
fn hierarchical_matches_boxed_engine_at_pinned_sizes() {
    for n in PINNED {
        assert_equiv(
            &family_spec(n, CollectiveAlgo::NicHierarchical),
            &format!("hierarchical/n={n}"),
        );
    }
}

#[test]
fn inswitch_matches_boxed_engine_at_pinned_sizes() {
    for n in PINNED {
        assert_equiv(&family_spec(n, CollectiveAlgo::SwitchReduce), &format!("in-switch/n={n}"));
    }
}

/// Node counts the parallel executive is pinned at (2048 exercises 256
/// leaf partitions; 128 keeps a small-window regime in the mix).
const PAR_PINNED: [usize; 2] = [128, 2048];
/// Worker-thread counts every parallel pin runs under.
const PAR_THREADS: [usize; 3] = [1, 2, 4];

/// Single-layer variant of [`family_spec`], sized so the 2048-node ring
/// stays debug-build fast (event count scales with n², not hidden).
fn par_family_spec(n: usize, algo: CollectiveAlgo) -> ClusterSpec {
    let (leaves, m) = leaf_shape(n);
    let sys = planner_system(leaves, m);
    let topo = Topology::leaf_spine(leaves, m, 4.0);
    let w = Workload {
        layers: 1,
        hidden: if n >= 2048 { 128 } else { 256 },
        batch_per_node: 64,
    };
    ClusterSpec::new(sys, n).with_topology(topo).with_job(
        JobSpec::new("j0", SystemKind::SmartNic { bfp: false }, w, topo.contiguous_ranks(n))
            .with_layer_algos(vec![algo]),
    )
}

/// The parallel executive must agree with the sequential typed engine
/// within [`TOL`] at every thread count, and the parallel runs must be
/// bit-identical to each other (thread count must not change results).
fn assert_parallel_equiv(spec: &ClusterSpec, label: &str) {
    let typed = run_scenario_on(spec, EngineKind::Typed);
    let mut first: Option<ScenarioOutput> = None;
    for t in PAR_THREADS {
        let par = run_scenario_on(spec, EngineKind::Parallel { threads: t });
        assert_eq!(par.events, typed.events, "{label}/t={t}: event counts diverged");
        assert!(
            rel_err(typed.makespan, par.makespan) <= TOL,
            "{label}/t={t}: makespan parallel {} vs typed {}",
            par.makespan,
            typed.makespan
        );
        for (p, s) in par.jobs.iter().zip(&typed.jobs) {
            assert_eq!(p.ar_count, s.ar_count, "{label}/t={t}/{}", p.name);
            assert!(
                rel_err(s.duration, p.duration) <= TOL,
                "{label}/t={t}/{}: parallel {} vs typed {}",
                p.name,
                p.duration,
                s.duration
            );
            assert!(
                rel_err(s.mean_ar, p.mean_ar) <= TOL,
                "{label}/t={t}/{}: mean AR parallel {} vs typed {}",
                p.name,
                p.mean_ar,
                s.mean_ar
            );
        }
        match &first {
            None => first = Some(par),
            Some(f) => {
                assert_eq!(
                    f.makespan.to_bits(),
                    par.makespan.to_bits(),
                    "{label}/t={t}: thread count changed the makespan"
                );
                for (a, b) in f.jobs.iter().zip(&par.jobs) {
                    assert_eq!(
                        a.duration.to_bits(),
                        b.duration.to_bits(),
                        "{label}/t={t}/{}: thread count changed the duration",
                        a.name
                    );
                }
            }
        }
    }
}

/// The checked executive must reproduce the typed engine within [`TOL`]
/// at every audited thread count, stay bit-identical across those thread
/// counts, check every dispatch, and report zero violations (engine
/// invariants and the cluster conservation ledger both).
fn assert_checked_equiv(spec: &ClusterSpec, label: &str) {
    let typed = run_scenario_on(spec, EngineKind::Typed);
    assert!(typed.audit.is_none(), "{label}: unchecked engines must not carry a report");
    let mut first: Option<ScenarioOutput> = None;
    for t in PAR_THREADS {
        let out = run_scenario_on(spec, EngineKind::Checked { threads: t });
        let report = out.audit.as_ref().expect("checked engine carries a report");
        assert!(report.is_clean(), "{label}/t={t}: {}", report.summary());
        assert_eq!(
            report.events_checked(),
            out.events,
            "{label}/t={t}: every dispatch must be checked"
        );
        assert_eq!(out.events, typed.events, "{label}/t={t}: event counts diverged");
        assert!(
            rel_err(typed.makespan, out.makespan) <= TOL,
            "{label}/t={t}: makespan checked {} vs typed {}",
            out.makespan,
            typed.makespan
        );
        for (c, s) in out.jobs.iter().zip(&typed.jobs) {
            assert_eq!(c.ar_count, s.ar_count, "{label}/t={t}/{}", c.name);
            assert!(
                rel_err(s.duration, c.duration) <= TOL,
                "{label}/t={t}/{}: checked {} vs typed {}",
                c.name,
                c.duration,
                s.duration
            );
        }
        match &first {
            None => first = Some(out),
            Some(f) => {
                assert_eq!(
                    f.makespan.to_bits(),
                    out.makespan.to_bits(),
                    "{label}/t={t}: thread count changed the audited makespan"
                );
                for (a, b) in f.jobs.iter().zip(&out.jobs) {
                    assert_eq!(
                        a.duration.to_bits(),
                        b.duration.to_bits(),
                        "{label}/t={t}/{}: thread count changed the audited duration",
                        a.name
                    );
                }
            }
        }
    }
}

#[test]
fn parallel_ring_matches_typed_at_pinned_sizes() {
    for n in PAR_PINNED {
        assert_parallel_equiv(&par_family_spec(n, CollectiveAlgo::NicRing), &format!("ring/n={n}"));
    }
}

#[test]
fn parallel_binomial_matches_typed_at_pinned_sizes() {
    for n in PAR_PINNED {
        assert_parallel_equiv(
            &par_family_spec(n, CollectiveAlgo::NicBinomial),
            &format!("binomial/n={n}"),
        );
    }
}

#[test]
fn parallel_rabenseifner_matches_typed_at_pinned_sizes() {
    for n in PAR_PINNED {
        assert_parallel_equiv(
            &par_family_spec(n, CollectiveAlgo::NicRabenseifner),
            &format!("rabenseifner/n={n}"),
        );
    }
}

#[test]
fn parallel_hierarchical_matches_typed_at_pinned_sizes() {
    for n in PAR_PINNED {
        assert_parallel_equiv(
            &par_family_spec(n, CollectiveAlgo::NicHierarchical),
            &format!("hierarchical/n={n}"),
        );
    }
}

#[test]
fn parallel_inswitch_matches_typed_at_pinned_sizes() {
    for n in PAR_PINNED {
        assert_parallel_equiv(
            &par_family_spec(n, CollectiveAlgo::SwitchReduce),
            &format!("in-switch/n={n}"),
        );
    }
}

#[test]
fn parallel_multi_tenant_faulty_scenario_matches_typed() {
    // two jobs sharing nodes under straggler and degraded-link
    // injection, on a 2-leaf fabric so ring traffic crosses partitions
    // while the host job's rounds run on the coordinator
    let sys = SystemParams::smartnic_40g();
    let w = Workload {
        layers: 3,
        hidden: 256,
        batch_per_node: 32,
    };
    let topo = Topology::leaf_spine(2, 4, 4.0);
    let spec = ClusterSpec::new(sys, 8)
        .with_topology(topo)
        .with_faults(ClusterFaults::none().with_straggler(2, 0.5).with_degraded_link(5, 0.25))
        .with_job(JobSpec::new(
            "nic",
            SystemKind::SmartNic { bfp: true },
            w,
            topo.contiguous_ranks(8),
        ))
        .with_job(
            JobSpec::new(
                "host",
                SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
                w,
                vec![1, 3, 5, 7],
            )
            .starting_at(2e-4),
        );
    assert_parallel_equiv(&spec, "parallel-multi-tenant");
}

#[test]
fn checked_ring_is_bit_identical_and_clean_at_pinned_sizes() {
    for n in PAR_PINNED {
        assert_checked_equiv(&par_family_spec(n, CollectiveAlgo::NicRing), &format!("ring/n={n}"));
    }
}

#[test]
fn checked_binomial_is_bit_identical_and_clean_at_pinned_sizes() {
    for n in PAR_PINNED {
        assert_checked_equiv(
            &par_family_spec(n, CollectiveAlgo::NicBinomial),
            &format!("binomial/n={n}"),
        );
    }
}

#[test]
fn checked_rabenseifner_is_bit_identical_and_clean_at_pinned_sizes() {
    for n in PAR_PINNED {
        assert_checked_equiv(
            &par_family_spec(n, CollectiveAlgo::NicRabenseifner),
            &format!("rabenseifner/n={n}"),
        );
    }
}

#[test]
fn checked_hierarchical_is_bit_identical_and_clean_at_pinned_sizes() {
    for n in PAR_PINNED {
        assert_checked_equiv(
            &par_family_spec(n, CollectiveAlgo::NicHierarchical),
            &format!("hierarchical/n={n}"),
        );
    }
}

#[test]
fn checked_inswitch_is_bit_identical_and_clean_at_pinned_sizes() {
    for n in PAR_PINNED {
        assert_checked_equiv(
            &par_family_spec(n, CollectiveAlgo::SwitchReduce),
            &format!("in-switch/n={n}"),
        );
    }
}

#[test]
fn checked_multi_tenant_faulty_scenario_is_clean() {
    // the hardest determinism surface (shared servers, fault injection,
    // host rounds on the coordinator) must also audit clean
    let sys = SystemParams::smartnic_40g();
    let w = Workload {
        layers: 3,
        hidden: 256,
        batch_per_node: 32,
    };
    let topo = Topology::leaf_spine(2, 4, 4.0);
    let spec = ClusterSpec::new(sys, 8)
        .with_topology(topo)
        .with_faults(ClusterFaults::none().with_straggler(2, 0.5).with_degraded_link(5, 0.25))
        .with_job(JobSpec::new(
            "nic",
            SystemKind::SmartNic { bfp: true },
            w,
            topo.contiguous_ranks(8),
        ))
        .with_job(
            JobSpec::new(
                "host",
                SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
                w,
                vec![1, 3, 5, 7],
            )
            .starting_at(2e-4),
        );
    assert_checked_equiv(&spec, "checked-multi-tenant");
}

/// The four non-all-reduce kinds of the collective zoo (ISSUE 9), each
/// held to the full cross-engine bar below.
const ZOO: [CollectiveKind; 4] = [
    CollectiveKind::Broadcast,
    CollectiveKind::Allgather,
    CollectiveKind::ReduceScatter,
    CollectiveKind::AllToAll,
];

/// Single-layer spec running one collective of `kind` under `algo` on
/// the planner study's fabric (the [`par_family_spec`] shape, kind-aware).
fn zoo_spec(n: usize, kind: CollectiveKind, algo: CollectiveAlgo) -> ClusterSpec {
    let (leaves, m) = leaf_shape(n);
    let sys = planner_system(leaves, m);
    let topo = Topology::leaf_spine(leaves, m, 4.0);
    let w = Workload {
        layers: 1,
        hidden: if n >= 2048 { 128 } else { 256 },
        batch_per_node: 64,
    };
    ClusterSpec::new(sys, n).with_topology(topo).with_job(
        JobSpec::new("j0", SystemKind::SmartNic { bfp: false }, w, topo.contiguous_ranks(n))
            .with_layer_algos(vec![algo])
            .with_layer_kinds(vec![kind]),
    )
}

/// MoE-style trainer iteration: an all-to-all (expert dispatch)
/// interleaved with an all-reduce (dense gradients) in one two-layer
/// job, both planner-selected.
fn moe_spec(n: usize) -> ClusterSpec {
    let (leaves, m) = leaf_shape(n);
    let sys = planner_system(leaves, m);
    let topo = Topology::leaf_spine(leaves, m, 4.0);
    let w = Workload {
        layers: 2,
        hidden: if n >= 2048 { 128 } else { 256 },
        batch_per_node: 64,
    };
    ClusterSpec::new(sys, n).with_topology(topo).with_job(
        JobSpec::new("moe", SystemKind::SmartNic { bfp: false }, w, topo.contiguous_ranks(n))
            .with_layer_algos(vec![CollectiveAlgo::Auto; 2])
            .with_layer_kinds(vec![CollectiveKind::AllToAll, CollectiveKind::AllReduce]),
    )
}

#[test]
fn parallel_collective_zoo_matches_typed_at_pinned_sizes() {
    // every new kind through the planner (Auto), at both parallel pins
    for kind in ZOO {
        for n in PAR_PINNED {
            assert_parallel_equiv(
                &zoo_spec(n, kind, CollectiveAlgo::Auto),
                &format!("{}/n={n}", kind.name()),
            );
        }
    }
}

#[test]
fn parallel_switch_multicast_broadcast_matches_typed_at_pinned_sizes() {
    // the replication executor explicitly (SwitchReduce pins the
    // switch-multicast plan for a broadcast), 2048 nodes included
    for n in PAR_PINNED {
        assert_parallel_equiv(
            &zoo_spec(n, CollectiveKind::Broadcast, CollectiveAlgo::SwitchReduce),
            &format!("switch-multicast/n={n}"),
        );
    }
}

#[test]
fn parallel_moe_interleaved_scenario_matches_typed() {
    for n in PAR_PINNED {
        assert_parallel_equiv(&moe_spec(n), &format!("moe/n={n}"));
    }
}

#[test]
fn checked_collective_zoo_is_bit_identical_and_clean_at_pinned_sizes() {
    // the same matrix under the invariant auditor: clean reports (the
    // per-kind conservation ledger included), every dispatch checked,
    // bit-identical across audited thread counts
    for kind in ZOO {
        for n in PAR_PINNED {
            assert_checked_equiv(
                &zoo_spec(n, kind, CollectiveAlgo::Auto),
                &format!("{}/n={n}", kind.name()),
            );
        }
    }
}

#[test]
fn checked_switch_multicast_broadcast_is_clean_at_pinned_sizes() {
    for n in PAR_PINNED {
        assert_checked_equiv(
            &zoo_spec(n, CollectiveKind::Broadcast, CollectiveAlgo::SwitchReduce),
            &format!("switch-multicast/n={n}"),
        );
    }
}

#[test]
fn checked_moe_interleaved_scenario_is_clean() {
    for n in PAR_PINNED {
        assert_checked_equiv(&moe_spec(n), &format!("moe/n={n}"));
    }
}

#[test]
fn collective_zoo_is_deterministic_run_to_run() {
    // same spec, same thread count: bit-identical results for the
    // interleaved MoE job and for a forced switch-multicast broadcast
    for spec in [
        moe_spec(128),
        zoo_spec(128, CollectiveKind::Broadcast, CollectiveAlgo::SwitchReduce),
    ] {
        let a = run_scenario_on(&spec, EngineKind::Parallel { threads: 4 });
        let b = run_scenario_on(&spec, EngineKind::Parallel { threads: 4 });
        assert_eq!(a.events, b.events);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "nondeterministic makespan");
        assert_eq!(
            a.jobs[0].duration.to_bits(),
            b.jobs[0].duration.to_bits(),
            "nondeterministic job duration"
        );
    }
}

#[test]
fn parallel_engine_is_deterministic_run_to_run() {
    // same spec, same thread count: bit-identical results
    let spec = par_family_spec(128, CollectiveAlgo::NicRing);
    let a = run_scenario_on(&spec, EngineKind::Parallel { threads: 4 });
    let b = run_scenario_on(&spec, EngineKind::Parallel { threads: 4 });
    assert_eq!(a.events, b.events);
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "nondeterministic parallel makespan");
    assert_eq!(
        a.jobs[0].duration.to_bits(),
        b.jobs[0].duration.to_bits(),
        "nondeterministic parallel job duration"
    );
}

#[test]
fn e6_operating_points_identical_across_engines() {
    // the acceptance bar: at the paper's E6 operating points the typed
    // engine must land on the previous engine's virtual time within 1e-9
    let sys = SystemParams::smartnic_40g();
    for batch in [448, 1792] {
        let w = Workload::paper_mlp(batch);
        for bfp in [false, true] {
            let kind = SystemKind::SmartNic { bfp };
            let faults = ClusterFaults::none();
            let typed =
                simulate_iteration_unified_on(kind, &sys, &w, 6, &faults, EngineKind::Typed);
            let boxed = simulate_iteration_unified_on(
                kind,
                &sys,
                &w,
                6,
                &faults,
                EngineKind::BoxedBaseline,
            );
            let err = rel_err(boxed.breakdown.t_total, typed.breakdown.t_total);
            assert!(
                err <= TOL,
                "B={batch} bfp={bfp}: typed {} vs boxed {} ({err:.2e})",
                typed.breakdown.t_total,
                boxed.breakdown.t_total
            );
            assert!(rel_err(boxed.t_ar_layer, typed.t_ar_layer) <= TOL);
        }
    }
}

#[test]
fn multi_tenant_faulty_scenario_identical_across_engines() {
    // two jobs sharing nodes (NIC ring + host MPI) under straggler and
    // degraded-link injection: heavy tie traffic on shared servers
    let sys = SystemParams::smartnic_40g();
    let w = Workload {
        layers: 3,
        hidden: 256,
        batch_per_node: 32,
    };
    let spec = ClusterSpec::new(sys, 8)
        .with_faults(ClusterFaults::none().with_straggler(2, 0.5).with_degraded_link(5, 0.25))
        .with_job(JobSpec::new(
            "nic",
            SystemKind::SmartNic { bfp: true },
            w,
            (0..8).collect(),
        ))
        .with_job(
            JobSpec::new(
                "host",
                SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
                w,
                vec![1, 3, 5, 7],
            )
            .starting_at(2e-4),
        );
    assert_equiv(&spec, "multi-tenant");
}

#[test]
fn typed_engine_is_deterministic_under_ties() {
    // identical specs must produce identical traces run-to-run, and a
    // burst of same-instant events must drain in insertion order
    let spec = family_spec(32, CollectiveAlgo::NicRing);
    let a = run_scenario_on(&spec, EngineKind::Typed);
    let b = run_scenario_on(&spec, EngineKind::Typed);
    assert_eq!(a.events, b.events);
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "nondeterministic makespan");
    assert_eq!(
        a.jobs[0].duration.to_bits(),
        b.jobs[0].duration.to_bits(),
        "nondeterministic job duration"
    );
}

/// Minimal world for the engine-contract tests below.
struct TieLog {
    fired: Vec<u32>,
}

impl World for TieLog {
    type Event = u32;
    fn handle(_sim: &mut Sim<Self>, state: &mut Self, event: u32) {
        state.fired.push(event);
    }
}

#[test]
fn simultaneous_events_fire_in_insertion_order_on_both_engines() {
    for kind in [EngineKind::Typed, EngineKind::BoxedBaseline] {
        let mut sim: Sim<TieLog> = Sim::with_engine(kind);
        let mut log = TieLog { fired: Vec::new() };
        for i in 0..1000 {
            sim.schedule_at(1e-3, i);
        }
        sim.run(&mut log);
        assert_eq!(log.fired, (0..1000).collect::<Vec<_>>(), "{kind:?}");
    }
}

#[test]
#[should_panic(expected = "past")]
fn scheduling_into_the_past_still_panics() {
    let mut sim: Sim<TieLog> = Sim::new();
    sim.schedule_closure(1.0, |sim, _state| {
        sim.schedule_at(0.25, 9);
    });
    sim.run(&mut TieLog { fired: Vec::new() });
}

#[test]
#[should_panic(expected = "finite")]
fn scheduling_non_finite_times_still_panics() {
    let mut sim: Sim<TieLog> = Sim::new();
    sim.schedule_at(f64::INFINITY, 0);
}

// ---------------------- churn-trace equivalence -----------------------
//
// The gang scheduler (PR 8) folds job arrival, preemption,
// checkpoint-restart, elastic resize and node repair into the same event
// loop.  All scheduler events route to the coordinator partition and are
// only emitted by coordinator events, so a churn-heavy trace is held to
// the exact same bar as the static scenarios: bit-identical across
// `Typed` and `Parallel {1, 2, 4}`, clean and bit-identical under
// `Checked`, and run-to-run deterministic down to the JCT percentiles.

/// A deliberately churny 32-node trace: heavy-tailed gangs, elastic
/// resizes on ~40% of jobs, two node failures mid-trace.
fn churn_spec(seed: u64) -> TraceSpec {
    let (leaves, npl) = leaf_shape(32);
    synth_trace(
        planner_system(leaves, npl),
        Topology::leaf_spine(leaves, npl, 4.0),
        Policy::FragAllowed,
        &TraceGenConfig {
            jobs: 16,
            seed,
            mean_interarrival: 0.01,
            min_gang: 2,
            max_gang: 12,
            max_iters: 3,
            layers: 2,
            hidden: 64,
            batch_per_node: 8,
            elastic_fraction: 0.4,
            failures: 2,
            restart_delay: 0.01,
            repair_delay: 0.05,
        },
    )
}

fn assert_trace_bits_equal(a: &TraceOutput, b: &TraceOutput, label: &str) {
    assert_eq!(a.events, b.events, "{label}: event counts diverged");
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{label}: makespan diverged");
    assert_eq!(a.jobs.len(), b.jobs.len(), "{label}: job counts diverged");
    for (x, y) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(x.name, y.name, "{label}: job order diverged");
        assert_eq!(
            x.first_placed.to_bits(),
            y.first_placed.to_bits(),
            "{label}/{}: first placement diverged",
            x.name
        );
        assert_eq!(
            x.completed.to_bits(),
            y.completed.to_bits(),
            "{label}/{}: completion diverged",
            x.name
        );
        assert_eq!(x.preemptions, y.preemptions, "{label}/{}: preemptions", x.name);
        assert_eq!(x.restarts, y.restarts, "{label}/{}: restarts", x.name);
        assert_eq!(x.iters, y.iters, "{label}/{}: iteration counts", x.name);
    }
}

// ------------------- multi-tenant tenancy equivalence ------------------
//
// The in-switch tenancy layer (ISSUE 10) — per-flow table admission, LRU
// eviction, engine-occupancy serialization, PFC derating — mutates shared
// fabric state from `Switch*` and job-wake events, all of which route to
// the global/coordinator partition.  It is therefore held to the full
// cross-engine bar: contended scenarios (2 and 4 tenants, paused and
// calm) must agree across `Typed`/`Parallel {1,2,4}`/`Checked {1,2,4}`
// at both parallel pins, with identical admission tallies, and eviction
// decisions must be run-to-run deterministic.

/// `tenants` disjoint jobs sharing one reduction tier: two ranks in each
/// leaf, all rooted in leaf 0, the table sized to hold exactly `slots`
/// gradients, optional PFC pause pressure, job `j` starting at
/// `j * stagger`.
fn tenancy_spec(n: usize, tenants: usize, slots: usize, pause: bool, stagger: f64) -> ClusterSpec {
    let (leaves, m) = leaf_shape(n);
    assert!(2 * tenants <= m, "tenant placements must stay disjoint");
    let hidden = if n >= 2048 { 128 } else { 512 };
    let payload = (hidden * hidden * 4) as f64;
    let base = planner_system(leaves, m);
    let mut switch = base.switch;
    switch.reduce_table_bytes = payload * slots as f64;
    let sys = base.with_switch_reduction(switch).with_pfc(if pause {
        PfcParams { pause_rate: 100.0, pause_window: 1e-3 }
    } else {
        PfcParams::off()
    });
    let topo = Topology::leaf_spine(leaves, m, 4.0);
    let w = Workload {
        layers: 1,
        hidden,
        batch_per_node: 64,
    };
    let mut spec = ClusterSpec::new(sys, n).with_topology(topo);
    for j in 0..tenants {
        let ranks = (0..leaves).flat_map(|l| [l * m + 2 * j, l * m + 2 * j + 1]).collect();
        spec = spec.with_job(
            JobSpec::new(&format!("tenant{j}"), SystemKind::SmartNic { bfp: false }, w, ranks)
                .with_layer_algos(vec![CollectiveAlgo::SwitchReduce])
                .starting_at(j as f64 * stagger),
        );
    }
    spec
}

/// The contended matrix: 2 tenants into a 1-slot table and 4 tenants
/// into a 2-slot table (half admitted, half per-flow fallback), calm and
/// paused.
const TENANCY_MATRIX: [(usize, usize, bool); 4] =
    [(2, 1, false), (2, 1, true), (4, 2, false), (4, 2, true)];

#[test]
fn parallel_contended_tenancy_matches_typed_at_pinned_sizes() {
    for n in PAR_PINNED {
        for (tenants, slots, pause) in TENANCY_MATRIX {
            assert_parallel_equiv(
                &tenancy_spec(n, tenants, slots, pause, 0.0),
                &format!("tenancy/n={n}/k={tenants}/pause={pause}"),
            );
        }
    }
}

#[test]
fn checked_contended_tenancy_is_clean_at_pinned_sizes() {
    for n in PAR_PINNED {
        for (tenants, slots, pause) in TENANCY_MATRIX {
            assert_checked_equiv(
                &tenancy_spec(n, tenants, slots, pause, 0.0),
                &format!("tenancy/n={n}/k={tenants}/pause={pause}"),
            );
        }
    }
}

#[test]
fn tenancy_outcomes_agree_across_every_engine() {
    // the admission tallies themselves — not just virtual times — must
    // be engine-independent: same admitted/evicted/fallback partition,
    // same eviction count, per job and in aggregate
    for n in PAR_PINNED {
        let spec = tenancy_spec(n, 4, 2, true, 0.0);
        let typed = run_scenario_on(&spec, EngineKind::Typed);
        assert_eq!(typed.tenancy.requested, 4, "n={n}: every tenant must be classified");
        assert_eq!(typed.tenancy.admitted, 2, "n={n}: a 2-slot table admits two tenants");
        for t in PAR_THREADS {
            for kind in [EngineKind::Parallel { threads: t }, EngineKind::Checked { threads: t }] {
                let out = run_scenario_on(&spec, kind);
                assert_eq!(out.tenancy, typed.tenancy, "n={n}/{kind:?}: aggregate tallies");
                for (a, b) in out.jobs.iter().zip(&typed.jobs) {
                    assert_eq!(a.tenancy, b.tenancy, "n={n}/{kind:?}/{}", a.name);
                }
            }
        }
    }
}

#[test]
fn eviction_decisions_are_deterministic_run_to_run_and_across_engines() {
    // tenant0 finishes and leaves its slot warm (idle, sticky); tenant1
    // posts half a second later into a full table and must evict it —
    // the same decision, bit for bit, on every engine and every run
    let spec = tenancy_spec(128, 2, 1, false, 0.5);
    let a = run_scenario_on(&spec, EngineKind::Typed);
    let b = run_scenario_on(&spec, EngineKind::Typed);
    assert_eq!(a.tenancy, b.tenancy, "run-to-run tenancy tallies diverged");
    assert!(a.tenancy.table_evictions >= 1, "the late tenant must evict the warm slot");
    assert_eq!(a.tenancy.admitted, 2, "both tenants should win the table in turn");
    assert_eq!(a.tenancy.fallback + a.tenancy.evicted, 0);
    for t in PAR_THREADS {
        for kind in [EngineKind::Parallel { threads: t }, EngineKind::Checked { threads: t }] {
            let out = run_scenario_on(&spec, kind);
            assert_eq!(out.tenancy, a.tenancy, "{kind:?}: tenancy tallies diverged");
            for (x, y) in out.jobs.iter().zip(&a.jobs) {
                assert_eq!(x.tenancy, y.tenancy, "{kind:?}/{}", x.name);
            }
        }
    }
}

#[test]
fn churn_trace_is_bit_identical_across_engines_and_threads() {
    let spec = churn_spec(7);
    let typed = run_trace(&spec, EngineKind::Typed);
    assert!(typed.audit.is_none(), "unchecked engines must not carry a report");
    for t in PAR_THREADS {
        let par = run_trace(&spec, EngineKind::Parallel { threads: t });
        assert_trace_bits_equal(&typed, &par, &format!("churn/parallel-t{t}"));
        // bit-identity subsumes the 1e-9 virtual-time bar, but pin the
        // tolerance form too so a future weakening of the bit gate still
        // has a floor
        assert!(rel_err(typed.makespan, par.makespan) <= TOL, "churn/parallel-t{t}");
    }
}

#[test]
fn churn_trace_checked_is_clean_and_bit_identical() {
    let spec = churn_spec(7);
    let typed = run_trace(&spec, EngineKind::Typed);
    for t in PAR_THREADS {
        let out = run_trace(&spec, EngineKind::Checked { threads: t });
        let report = out.audit.as_ref().expect("checked engine carries a report");
        assert!(report.is_clean(), "churn/checked-t{t}: {}", report.summary());
        assert_eq!(
            report.events_checked(),
            out.events,
            "churn/checked-t{t}: every dispatch must be checked"
        );
        assert_trace_bits_equal(&typed, &out, &format!("churn/checked-t{t}"));
    }
}

#[test]
fn churn_trace_percentiles_are_run_to_run_deterministic() {
    // same seed => same trace => identical p50/p99 JCT, bit for bit
    for seed in [7, 23] {
        let a = run_trace(&churn_spec(seed), EngineKind::Typed);
        let b = run_trace(&churn_spec(seed), EngineKind::Typed);
        let jcts = |o: &TraceOutput| o.jobs.iter().map(|j| j.jct).collect::<Vec<_>>();
        let (ja, jb) = (jcts(&a), jcts(&b));
        for p in [50.0, 99.0] {
            assert_eq!(
                percentile(&ja, p).to_bits(),
                percentile(&jb, p).to_bits(),
                "seed {seed}: p{p} JCT diverged run-to-run"
            );
        }
        assert_eq!(a.events, b.events, "seed {seed}: event counts diverged run-to-run");
    }
}
