//! Adversarial multi-tenant tenancy suite (see docs/INVARIANTS.md):
//!
//!  * admission partition: every switch-requesting flow lands in exactly
//!    one of {admitted, evicted, fallback} — across random tenant counts,
//!    table scales and layer counts
//!  * per-flow fallback: a refused tenant runs bit-identically to the
//!    same job's host/NIC plan run standalone
//!  * occupancy derating: the contended in-switch all-reduce matches the
//!    closed form with the engine-occupancy pipeline term
//!  * degenerate table: a zero-capacity table reproduces the per-switch
//!    fallback (PR 3) exactly; a sub-segment table reproduces it per flow
//!
//! Exact float comparisons here are deliberate: the engine is
//! deterministic, and the fallback paths must be *the same code*, not a
//! lookalike.
#![allow(clippy::float_cmp)]

use ai_smartnic::analytic::model::{inswitch_ar_time_contended, SystemKind};
use ai_smartnic::cluster::{run_scenario, ClusterSpec, CollectiveAlgo, JobSpec, Topology};
use ai_smartnic::experiments::tenancy::{
    tenancy_system, tenant_ranks, LEAVES, NODES_PER_LEAF,
};
use ai_smartnic::prop::{forall, gens};
use ai_smartnic::sysconfig::{SwitchParams, SystemParams, Workload};

const HIDDEN: usize = 1024; // 4 MiB payload: 16 segments of 256 KiB

/// `tenants` identical jobs on the shared leaf–spine reduction tier
/// (the experiment's geometry: two ranks in each of four leaves, all
/// rooted in leaf 0), every layer forced through `algo`.
fn contended_spec(
    tenants: usize,
    table_scale: f64,
    layers: usize,
    algo: CollectiveAlgo,
) -> ClusterSpec {
    let sys = tenancy_system(table_scale, 0.0);
    let topo = Topology::leaf_spine(LEAVES, NODES_PER_LEAF, 4.0);
    let w = Workload {
        layers,
        hidden: HIDDEN,
        batch_per_node: 64,
    };
    let mut spec = ClusterSpec::new(sys, topo.nodes()).with_topology(topo);
    for j in 0..tenants {
        spec = spec.with_job(
            JobSpec::new(
                &format!("tenant{j}"),
                SystemKind::SmartNic { bfp: false },
                w,
                tenant_ranks(j),
            )
            .with_layer_algos(vec![algo; layers]),
        );
    }
    spec
}

#[test]
fn admission_outcomes_partition_every_requesting_flow() {
    // with SwitchReduce forced on a reduction-capable fabric, *every*
    // flow must be classified: admitted + evicted + fallback == flows,
    // at the aggregate and per job, whatever the contention level
    let scales = [1.0 / 64.0, 1.0, 4.0];
    let cases = gens::pair(
        gens::usize_in(1..=4),
        gens::pair(gens::usize_in(0..=2), gens::usize_in(1..=3)),
    );
    forall(&cases, 18, |&(tenants, (scale_idx, layers))| {
        let scale = scales[scale_idx];
        let out = run_scenario(&contended_spec(tenants, scale, layers, CollectiveAlgo::SwitchReduce));
        let flows: usize = out.jobs.iter().map(|j| j.ar_count).sum();
        let agg = out.tenancy;
        let per_job_ok = out.jobs.iter().all(|j| {
            j.tenancy.requested == layers
                && j.tenancy.admitted + j.tenancy.evicted + j.tenancy.fallback == layers
        });
        let sums_ok = agg.requested == flows
            && flows == tenants * layers
            && agg.admitted == out.jobs.iter().map(|j| j.tenancy.admitted).sum::<usize>()
            && agg.evicted == out.jobs.iter().map(|j| j.tenancy.evicted).sum::<usize>()
            && agg.fallback == out.jobs.iter().map(|j| j.tenancy.fallback).sum::<usize>();
        // a sub-segment table can admit nobody; a 4x table holds every
        // job's single refcounted reservation
        let scale_ok = match scale_idx {
            0 => agg.admitted == 0,
            2 => agg.fallback == 0 && agg.evicted == 0,
            _ => true,
        };
        per_job_ok && sums_ok && scale_ok
    });
}

/// Flat 8-port switch whose table holds exactly one 4 MiB gradient.
fn one_slot_flat_sys() -> SystemParams {
    let base = SystemParams::smartnic_40g();
    let mut switch = SwitchParams::netreduce(8, &base.net);
    switch.reduce_table_bytes = 4.0 * 1024.0 * 1024.0;
    base.with_switch_reduction(switch)
}

fn flat_job(name: &str, ranks: Vec<usize>, algo: CollectiveAlgo) -> JobSpec {
    let w = Workload {
        layers: 1,
        hidden: HIDDEN,
        batch_per_node: 64,
    };
    JobSpec::new(name, SystemKind::SmartNic { bfp: false }, w, ranks).with_layer_algos(vec![algo])
}

#[test]
fn refused_tenant_runs_bit_identically_to_its_standalone_host_plan() {
    // two disjoint 4-rank tenants on one flat switch whose table holds
    // exactly one gradient: tenant a admits, tenant b is refused per
    // flow and must execute the *same* NIC ring it would run standalone
    let sys = one_slot_flat_sys();
    let contended = run_scenario(
        &ClusterSpec::new(sys, 8)
            .with_job(flat_job("a", (0..4).collect(), CollectiveAlgo::SwitchReduce))
            .with_job(flat_job("b", (4..8).collect(), CollectiveAlgo::SwitchReduce)),
    );
    assert_eq!(contended.jobs[0].tenancy.admitted, 1, "tenant a should hold the table");
    assert_eq!(contended.jobs[1].tenancy.fallback, 1, "tenant b should fall back per flow");
    assert_eq!(contended.tenancy.requested, 2);

    let solo = run_scenario(
        &ClusterSpec::new(sys, 8).with_job(flat_job("b", (4..8).collect(), CollectiveAlgo::NicRing)),
    );
    assert_eq!(solo.jobs[0].tenancy.requested, 0, "a NIC ring never asks the switch");
    assert_eq!(
        contended.jobs[1].duration.to_bits(),
        solo.jobs[0].duration.to_bits(),
        "fallback ring {} vs standalone ring {}",
        contended.jobs[1].duration,
        solo.jobs[0].duration
    );
    assert_eq!(contended.jobs[1].mean_ar.to_bits(), solo.jobs[0].mean_ar.to_bits());
}

#[test]
fn contended_inswitch_time_matches_the_occupancy_derated_closed_form() {
    // a 4x table admits every tenant in full (window == segs), so the
    // only contention is the shared root engine: the last tenant's
    // all-reduce must track fill + (tenants*segs - 1) * bottleneck
    let elems = HIDDEN * HIDDEN;
    let granted = elems as f64 * 4.0; // each tenant's full reservation
    let sys = tenancy_system(4.0, 0.0);
    let last_ar = |tenants: usize| {
        let out = run_scenario(&contended_spec(tenants, 4.0, 1, CollectiveAlgo::SwitchReduce));
        assert_eq!(out.tenancy.admitted, tenants, "4x table must admit all {tenants}");
        out.jobs.iter().map(|j| j.mean_ar).fold(0.0f64, f64::max)
    };
    // m = 2 ranks/leaf, l = 4 leaves, effective oversubscription 1.0
    // (2 of 8 ranks per leaf through a 4x-tapered uplink), duty 1.0
    let form =
        |tenants: usize| inswitch_ar_time_contended(&sys, elems, 2, LEAVES, 1.0, 1.0, tenants, granted, 1.0);

    let solo = last_ar(1);
    let solo_err = (solo - form(1)).abs() / form(1);
    assert!(solo_err < 1e-9, "solo: engine {} vs closed form {}", solo, form(1));

    let mut prev = solo;
    for tenants in [2, 4] {
        let measured = last_ar(tenants);
        assert!(measured > prev, "{tenants} tenants must finish later than {prev}");
        let err = (measured - form(tenants)).abs() / form(tenants);
        assert!(
            err < 0.05,
            "{tenants} tenants: engine {} vs contended form {} ({:.2}% off)",
            measured,
            form(tenants),
            err * 100.0
        );
        prev = measured;
    }
}

#[test]
fn zero_capacity_table_degenerates_to_the_per_switch_fallback_exactly() {
    // table = 0 disables the reduction tier outright: the planner never
    // sees an in-switch candidate, nothing is classified, and the run is
    // bit-identical to the forced NIC ring (PR 3's per-switch fallback)
    let zero = run_scenario(&contended_spec(1, 0.0, 1, CollectiveAlgo::SwitchReduce));
    let ring = run_scenario(&contended_spec(1, 0.0, 1, CollectiveAlgo::NicRing));
    assert_eq!(zero.tenancy.requested, 0, "no table, no admission request");
    assert_eq!(zero.jobs[0].duration.to_bits(), ring.jobs[0].duration.to_bits());
    assert_eq!(zero.jobs[0].mean_ar.to_bits(), ring.jobs[0].mean_ar.to_bits());

    // a sub-segment table keeps the tier alive but refuses each flow
    // individually: same ring timing, now classified as a fallback
    let tiny = run_scenario(&contended_spec(1, 1.0 / 64.0, 1, CollectiveAlgo::SwitchReduce));
    let tiny_ring = run_scenario(&contended_spec(1, 1.0 / 64.0, 1, CollectiveAlgo::NicRing));
    assert_eq!(tiny.jobs[0].tenancy.fallback, 1, "sub-segment table must refuse per flow");
    assert_eq!(tiny.jobs[0].duration.to_bits(), tiny_ring.jobs[0].duration.to_bits());
    assert_eq!(tiny.jobs[0].mean_ar.to_bits(), tiny_ring.jobs[0].mean_ar.to_bits());
}
