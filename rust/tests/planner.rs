// Property suite locking down the topology-aware planner and the
// in-switch reduction path (ISSUE 3):
//  * across randomized topologies/placements/sizes, the planner's chosen
//    plan is never predicted slower than any fixed single-scheme plan
//    (within 1e-9), and every candidate plan reduces the full vector
//    exactly once per element — the conservation invariant cross-checked
//    against `scheme_rounds`' ring decomposition;
//  * in-switch reduction with reduce rate → ∞ and no table pressure
//    converges to the pipelined no-contention lower bound (the closed
//    form is exact there); with a switch that cannot hold one segment it
//    degrades to the *exact* NIC ring path (fallback regression guard);
//  * the hierarchical plan measurably beats the strided NIC ring at 4:1
//    oversubscription on the unified engine;
//  * the calibrated-β E6 operating points are pinned with a tolerance so
//    β ≠ 1.0 can't silently break the paper validation.

use ai_smartnic::analytic::model::{inswitch_ar_time_elems, iteration, SystemKind};
use ai_smartnic::cluster::planner;
use ai_smartnic::cluster::{CollectiveAlgo, Topology};
use ai_smartnic::collective::timing::{scheme_rounds, HostNet};
use ai_smartnic::collective::Scheme;
use ai_smartnic::prop::{forall, gens};
use ai_smartnic::sysconfig::{SwitchParams, SystemParams, Workload};
use ai_smartnic::util::stats::rel_err;

/// Both placements for a random (leaves, nodes_per_leaf, oversub) shape.
fn shapes(leaves: usize, m: usize, oversub: f64) -> Vec<(Topology, Vec<usize>)> {
    let n = leaves * m;
    let ls = Topology::leaf_spine(leaves, m, oversub);
    vec![
        (Topology::flat(n), (0..n).collect()),
        (ls, ls.contiguous_ranks(n)),
        (ls, ls.strided_ranks(n)),
    ]
}

fn netreduce_sys(radix: usize) -> SystemParams {
    let s = SystemParams::smartnic_40g();
    s.with_switch_reduction(SwitchParams::netreduce(radix, &s.net))
}

#[test]
fn prop_planner_never_slower_than_any_fixed_plan() {
    // randomized leaf count, leaf size, oversubscription and message size;
    // the planner's pick must cost (by its own closed forms) no more than
    // any fixed single-scheme plan, with and without switch engines
    forall(
        &gens::pair(
            gens::pair(gens::usize_in(1..=4), gens::usize_in(2..=5)),
            gens::pair(gens::usize_in(0..=2), gens::usize_in(1_000..=4_000_000)),
        ),
        40,
        |&((leaves, m), (oversub_idx, elems))| {
            let oversub = [1.0, 2.0, 4.0][oversub_idx];
            for sys in [SystemParams::smartnic_40g(), netreduce_sys(m.max(leaves))] {
                for (topo, ranks) in shapes(leaves, m, oversub) {
                    let chosen = planner::plan(&sys, &topo, &ranks, elems, 1.0);
                    for cand in planner::candidates(&sys, &topo, &ranks, elems, 1.0) {
                        if chosen.predicted > cand.predicted + 1e-9 {
                            return false;
                        }
                        // a fixed request for an available family returns
                        // exactly that family at the same predicted cost
                        let fixed = planner::plan_fixed(&sys, &topo, &ranks, elems, 1.0, cand.kind);
                        if fixed.kind != cand.kind
                            || (fixed.predicted - cand.predicted).abs() > 1e-12
                        {
                            return false;
                        }
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_every_plan_reduces_each_element_once_per_peer() {
    // conservation: an n-rank all-reduce performs exactly (n-1)·E genuine
    // adds — the same count `scheme_rounds`' ring decomposition implies
    // (its n-1 reduce-scatter rounds move E/n per rank per round)
    let env = HostNet {
        net: SystemParams::smartnic_40g().net,
        step_overhead: 15.0e-6,
        comm_bw_cap: f64::INFINITY,
    };
    forall(
        &gens::pair(
            gens::pair(gens::usize_in(1..=4), gens::usize_in(2..=5)),
            gens::usize_in(1_000..=4_000_000),
        ),
        40,
        |&((leaves, m), elems)| {
            let sys = netreduce_sys(m.max(leaves));
            for (topo, ranks) in shapes(leaves, m, 4.0) {
                let n = ranks.len();
                // cross-check the target against scheme_rounds: ring has
                // 2(n-1) rounds, half of them reducing E/n per rank
                let plan = scheme_rounds(Scheme::Ring, n, elems as f64 * 4.0, &env);
                let rs_rounds = plan.rounds / 2;
                let want = rs_rounds as f64 * n as f64 * (elems as f64 / n as f64);
                if (want - (n as f64 - 1.0) * elems as f64).abs() > 1e-6 {
                    return false;
                }
                for cand in planner::candidates(&sys, &topo, &ranks, elems, 1.0) {
                    let got = cand.reduced_elems(n, elems);
                    if (got - want).abs() > want * 1e-9 + 1e-9 {
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// Mean AR latency of one paper-sized collective under `algo` (the
/// benchmark's shared measurement protocol).
fn measure_ar(sys: SystemParams, topo: Topology, ranks: Vec<usize>, algo: CollectiveAlgo) -> f64 {
    ai_smartnic::experiments::planner::measure_ar(sys, topo, ranks, algo, 2048)
}

#[test]
fn inswitch_infinite_rate_converges_to_the_lower_bound() {
    // reduce rate → ∞, table → ∞: the segment pipeline's only costs are
    // DMA, serialization and latency — the closed form is exact and sits
    // just above the one-gradient-per-Tx-link wire bound
    let ideal = SystemParams::smartnic_40g().with_switch_reduction(SwitchParams {
        reduce_flops: f64::INFINITY,
        reduce_table_bytes: 1e18,
    });
    let elems = 2048 * 2048;
    for (topo, ranks, m, l, eff_oversub) in [
        (Topology::flat(8), (0..8).collect::<Vec<_>>(), 8usize, 1usize, 1.0),
        (Topology::leaf_spine(2, 4, 4.0), (0..8).collect::<Vec<_>>(), 4, 2, 4.0),
        // partial-leaf placement: 2 of 8 ranks per leaf, so the effective
        // tapering is m·oversub/nodes_per_leaf = 2·4/8 = 1.0
        (Topology::leaf_spine(2, 8, 4.0), vec![0, 1, 8, 9], 2, 2, 1.0),
    ] {
        let measured = measure_ar(ideal, topo, ranks, CollectiveAlgo::SwitchReduce);
        let model = inswitch_ar_time_elems(&ideal, elems, m, l, eff_oversub, 1.0);
        let err = rel_err(model, measured);
        assert!(
            err < 1e-9,
            "{}: engine {measured} vs closed form {model} ({err:.2e})",
            topo.describe()
        );
        let wire_bound = elems as f64 * 4.0 / ideal.net.effective_bw();
        assert!(measured > wire_bound, "beats the wire bound: {measured}");
        assert!(
            measured < wire_bound * 1.1,
            "not converged: {measured} vs bound {wire_bound}"
        );
    }
}

#[test]
fn inswitch_without_capacity_degrades_to_the_exact_nic_ring() {
    // a switch with engines but a table that cannot hold one segment (or
    // no engines at all) must execute the *identical* NIC ring path
    let elems_topo = Topology::leaf_spine(2, 3, 4.0);
    let ranks: Vec<usize> = (0..6).collect();
    for crippled in [
        SystemParams::smartnic_40g(), // no engines
        SystemParams::smartnic_40g().with_switch_reduction(SwitchParams {
            reduce_flops: 1e12,
            reduce_table_bytes: 0.0, // capacity 0: disabled outright
        }),
        SystemParams::smartnic_40g().with_switch_reduction(SwitchParams {
            reduce_flops: 1e12,
            reduce_table_bytes: 1024.0, // < one segment: planner must fall back
        }),
    ] {
        let fb_algo = CollectiveAlgo::SwitchReduce;
        let ring = measure_ar(crippled, elems_topo, ranks.clone(), CollectiveAlgo::NicRing);
        let fallback = measure_ar(crippled, elems_topo, ranks.clone(), fb_algo);
        assert!(
            (ring - fallback).abs() <= ring * 1e-12,
            "fallback differs from the ring: {fallback} vs {ring}"
        );
    }
}

#[test]
fn hierarchical_plan_beats_the_strided_ring_on_the_engine() {
    // the tentpole claim, measured: 4 leaves x 8 ranks at 4:1, strided
    // placement — the hierarchical plan crosses the spine with shard
    // traffic only and must undercut the flat NIC ring's ~4x penalty
    let sys = SystemParams::smartnic_40g();
    let topo = Topology::leaf_spine(4, 8, 4.0);
    let ranks = topo.strided_ranks(32);
    let ring = measure_ar(sys, topo, ranks.clone(), CollectiveAlgo::NicRing);
    let hier = measure_ar(sys, topo, ranks.clone(), CollectiveAlgo::NicHierarchical);
    assert!(hier < ring * 0.85, "hierarchical {hier} vs strided ring {ring}");
    // Auto (whatever plan family it picks) must also recover a good part
    // of the strided penalty
    let auto = measure_ar(sys, topo, ranks, CollectiveAlgo::Auto);
    assert!(auto < ring * 0.9, "auto {auto} vs strided ring {ring}");
}

#[test]
fn switch_reduction_overtakes_the_nic_ring_when_provisioned() {
    // with line-rate engines the switch-side offload beats even the
    // contiguous NIC ring — but only while the switch tier is its own:
    // the win is conditional on tenancy, not universal (ISSUE 10)
    let sys = netreduce_sys(8);
    let topo = Topology::leaf_spine(4, 8, 4.0);
    let ranks = topo.contiguous_ranks(32);
    let ring = measure_ar(sys, topo, ranks.clone(), CollectiveAlgo::NicRing);
    let sw = measure_ar(sys, topo, ranks.clone(), CollectiveAlgo::SwitchReduce);
    assert!(sw < ring, "in-switch {sw} vs contiguous ring {ring}");

    // uncontended, the planner agrees and picks the in-switch plan ...
    let elems = 2048 * 2048;
    let idle = planner::plan_with(&sys, &topo, &ranks, elems, 1.0, planner::TenancyLoad::idle());
    assert_eq!(idle.kind, planner::PlanKind::InSwitch, "idle tier: in-switch must win");

    // ... but past the occupancy knee it must flip to a host/NIC plan:
    // eight tenants queueing on the shared engine octuple the pipeline
    // term while the ring is untouched
    let crowded = planner::TenancyLoad {
        tenants: 8,
        table_bytes: f64::INFINITY,
        pause_duty: 1.0,
    };
    let late = planner::plan_with(&sys, &topo, &ranks, elems, 1.0, crowded);
    assert_ne!(late.kind, planner::PlanKind::InSwitch, "8 tenants deep: in-switch must lose");
    assert!(late.predicted < idle.predicted * 8.0, "the fallback must dodge the queue");

    // a granted table share below one segment prices in-switch infeasible
    let starved = planner::TenancyLoad {
        tenants: 2,
        table_bytes: 1024.0,
        pause_duty: 1.0,
    };
    let t = planner::plan_with(&sys, &topo, &ranks, elems, 1.0, starved);
    assert_ne!(t.kind, planner::PlanKind::InSwitch, "sub-segment share: per-flow fallback");

    // ... and a pause storm (duty <= 0) stalls the tree outright
    let storm = planner::TenancyLoad {
        tenants: 1,
        table_bytes: f64::INFINITY,
        pause_duty: 0.0,
    };
    let s = planner::plan_with(&sys, &topo, &ranks, elems, 1.0, storm);
    assert_ne!(s.kind, planner::PlanKind::InSwitch, "pause storm: in-switch must be refused");
}

#[test]
fn e6_operating_points_pinned_under_calibrated_beta() {
    // golden iteration totals of the Sec. IV-C closed form at the paper's
    // operating points, computed under β = ethernet_framing_beta(9000) —
    // if a future recalibration moves any of these by > 1%, this fails
    // loudly instead of silently re-shaping every figure
    let nic = SystemParams::smartnic_40g();
    let base = SystemParams::baseline_100g();
    let pins: [(SystemKind, &SystemParams, usize, f64); 5] = [
        (SystemKind::SmartNic { bfp: false }, &nic, 448, 0.141147),
        (SystemKind::SmartNic { bfp: true }, &nic, 448, 0.106392),
        (SystemKind::SmartNic { bfp: false }, &nic, 1792, 0.318649),
        (
            SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
            &base,
            448,
            0.171040,
        ),
        (
            SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
            &base,
            1792,
            0.366557,
        ),
    ];
    for (kind, sys, batch, golden) in pins {
        let w = Workload::paper_mlp(batch);
        let t = iteration(kind, sys, &w, 6).t_total;
        let err = rel_err(golden, t);
        assert!(
            err < 0.01,
            "{} B={batch}: {t:.6} s vs pinned {golden:.6} s ({:.2}%)",
            kind.name(),
            err * 100.0
        );
    }
}
