// End-to-end training integration: real PJRT compute + real ring
// all-reduce (+ BFP wire quantization), small MLP, loss must fall.

use ai_smartnic::coordinator::{ArBackend, Optimizer, Trainer, TrainerConfig};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn cfg(backend: ArBackend, workers: usize) -> TrainerConfig {
    TrainerConfig {
        layers: 3,
        hidden: 64,
        batch_per_worker: 16,
        workers,
        lr: 0.04,
        seed: 42,
        backend,
        optimizer: Default::default(),
    }
}

#[test]
fn loss_decreases_fp32() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut t = Trainer::new(&dir, cfg(ArBackend::Fp32, 3)).unwrap();
    let stats = t.train(40, 0).unwrap();
    let first = stats[0].loss;
    let last = stats.last().unwrap().loss;
    assert!(
        last < first * 0.5,
        "loss did not fall: {first} -> {last}"
    );
    assert!(stats.iter().all(|s| s.loss.is_finite()));
}

#[test]
fn loss_decreases_bfp16_and_tracks_fp32() {
    // Paper Sec. IV-B: BFP16 gradient compression has minimal accuracy
    // impact — the compressed run must track the lossless one closely.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut t32 = Trainer::new(&dir, cfg(ArBackend::Fp32, 3)).unwrap();
    let mut t16 = Trainer::new(&dir, cfg(ArBackend::Bfp16, 3)).unwrap();
    let s32 = t32.train(40, 0).unwrap();
    let s16 = t16.train(40, 0).unwrap();
    let l32 = s32.last().unwrap().loss;
    let l16 = s16.last().unwrap().loss;
    assert!(l16 < s16[0].loss * 0.5, "bfp loss did not fall");
    let gap = (l16 - l32).abs() / l32.max(1e-9);
    assert!(gap < 0.35, "bfp diverged from fp32: {l32} vs {l16}");
}

#[test]
fn bfp_wire_bytes_are_compressed() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut t32 = Trainer::new(&dir, cfg(ArBackend::Fp32, 3)).unwrap();
    let mut t16 = Trainer::new(&dir, cfg(ArBackend::Bfp16, 3)).unwrap();
    let w32 = t32.step().unwrap().wire_bytes_per_node;
    let w16 = t16.step().unwrap().wire_bytes_per_node;
    let ratio = w32 / w16;
    // biases ride uncompressed, so slightly below the pure-weights 3.76
    assert!(ratio > 3.0, "wire compression only {ratio:.2}x");
}

#[test]
fn single_worker_trains_too() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut t = Trainer::new(&dir, cfg(ArBackend::Fp32, 1)).unwrap();
    let stats = t.train(15, 0).unwrap();
    assert!(stats.last().unwrap().loss < stats[0].loss);
    assert_eq!(stats[0].wire_bytes_per_node, 0.0);
}

#[test]
fn workers_scale_changes_nothing_structurally() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    for workers in [2usize, 4] {
        let mut t = Trainer::new(&dir, cfg(ArBackend::Bfp16, workers)).unwrap();
        let st = t.step().unwrap();
        assert!(st.loss.is_finite());
        assert!(st.wire_bytes_per_node > 0.0);
    }
}

#[test]
fn checkpoint_resume_is_bit_exact() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let ckpt = std::env::temp_dir().join("ai_smartnic_test_ckpt.json");

    // continuous run: 10 steps
    let mut a = Trainer::new(&dir, cfg(ArBackend::Bfp16, 3)).unwrap();
    let first5 = a.train(5, 0).unwrap();
    a.save_checkpoint(&ckpt).unwrap();
    let cont = a.train(5, 0).unwrap();

    // resumed run: fresh trainer + checkpoint -> same next 5 losses
    let mut b = Trainer::new(&dir, cfg(ArBackend::Bfp16, 3)).unwrap();
    b.load_checkpoint(&ckpt).unwrap();
    assert_eq!(b.step_count(), 5);
    let resumed = b.train(5, 0).unwrap();
    for (x, y) in cont.iter().zip(&resumed) {
        assert_eq!(x.loss, y.loss, "resume diverged at step {}", x.step);
    }
    assert!(first5[0].loss > cont.last().unwrap().loss);
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn checkpoint_shape_mismatch_rejected() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let ckpt = std::env::temp_dir().join("ai_smartnic_test_ckpt2.json");
    let a = Trainer::new(&dir, cfg(ArBackend::Fp32, 2)).unwrap();
    a.save_checkpoint(&ckpt).unwrap();
    let mut wrong = Trainer::new(
        &dir,
        TrainerConfig {
            layers: 4, // different depth
            ..cfg(ArBackend::Fp32, 2)
        },
    )
    .unwrap();
    assert!(wrong.load_checkpoint(&ckpt).is_err());
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn adam_optimizer_converges() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut c = cfg(ArBackend::Bfp16, 3);
    c.optimizer = Optimizer::Adam;
    c.lr = 0.01; // Adam wants a smaller lr on this task
    let mut t = Trainer::new(&dir, c).unwrap();
    let stats = t.train(40, 0).unwrap();
    let (first, last) = (stats[0].loss, stats.last().unwrap().loss);
    assert!(last < first * 0.6, "adam loss did not fall: {first} -> {last}");
    assert!(stats.iter().all(|s| s.loss.is_finite()));
}

#[test]
fn rejects_missing_shapes() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let bad = TrainerConfig {
        hidden: 999, // no artifacts for this width
        ..cfg(ArBackend::Fp32, 2)
    };
    assert!(Trainer::new(&dir, bad).is_err());
}
