// Golden-schema tests for the CI benchmark artifacts
// (`BENCH_scaling.json` from `smartnic scale`, `BENCH_planner.json` from
// `smartnic plan`): the exact key structure is pinned here and every
// document must survive a parse round-trip, so the artifact shape cannot
// drift without a test failure.

use ai_smartnic::experiments::{planner, scaling};
use ai_smartnic::util::json::Json;

/// Assert that every `/`-separated key path resolves in `doc`; a leading
/// `0` element indexes into an array.
fn assert_paths(doc: &Json, paths: &[&str]) {
    for path in paths {
        let mut cur = doc;
        for part in path.split('/') {
            cur = if let Ok(i) = part.parse::<usize>() {
                cur.idx(i)
                    .unwrap_or_else(|| panic!("missing array index '{part}' in '{path}'"))
            } else {
                cur.get(part)
                    .unwrap_or_else(|| panic!("missing key '{part}' in '{path}'"))
            };
        }
    }
}

#[test]
fn bench_scaling_schema_is_pinned() {
    let cfg = scaling::ScalingConfig {
        nodes: vec![8],
        leaves: 4,
        ..scaling::ScalingConfig::default()
    };
    let sweep = scaling::run_sweep(&cfg);
    let oversub = scaling::run_oversub(&cfg);
    assert!(!oversub.is_empty(), "8 nodes on 4 leaves must produce oversub points");
    let j = scaling::to_json(&cfg, &sweep, &oversub);
    assert_paths(
        &j,
        &[
            "config/batch",
            "config/leaves",
            "config/oversubscription",
            "config/validate_tol",
            "sweep/0/nodes",
            "sweep/0/model_s/baseline",
            "sweep/0/model_s/smartnic",
            "sweep/0/model_s/smartnic+bfp",
            "sweep/0/unified_s/baseline",
            "sweep/0/unified_s/smartnic",
            "sweep/0/unified_s/smartnic+bfp",
            "sweep/0/rel_err/baseline",
            "sweep/0/speedup_vs_baseline/model_nic",
            "sweep/0/speedup_vs_baseline/model_bfp",
            "sweep/0/speedup_vs_baseline/unified_nic",
            "sweep/0/speedup_vs_baseline/unified_bfp",
            "oversubscription_penalty/0/nodes",
            "oversubscription_penalty/0/scheme",
            "oversubscription_penalty/0/flat_ar_s",
            "oversubscription_penalty/0/spanning_ar_s",
            "oversubscription_penalty/0/penalty",
        ],
    );
    // round-trip: the writer's output parses back to the same document
    let parsed = Json::parse(&j.to_string_pretty()).expect("BENCH_scaling must parse");
    assert_eq!(parsed, j);
    // and numeric leaves stay numeric
    assert!(j.get("sweep").unwrap().idx(0).unwrap().get("nodes").unwrap().as_usize() == Some(8));
}

#[test]
fn bench_planner_schema_is_pinned() {
    let cfg = planner::PlannerConfig {
        nodes: vec![6],
        ..planner::PlannerConfig::default()
    };
    let points = planner::run(&cfg);
    assert_eq!(points.len(), 2, "contiguous + strided");
    let j = planner::to_json(&cfg, &points);
    let mut paths = vec![
        "config/oversubscription".to_string(),
        "config/hidden".to_string(),
        "config/inswitch_tol".to_string(),
        "gates/worst_inswitch_err".to_string(),
        "gates/hierarchical_beats_strided_ring".to_string(),
    ];
    for i in 0..2 {
        for key in ["nodes", "leaves", "placement", "chosen"] {
            paths.push(format!("points/{i}/{key}"));
        }
        for algo in planner::ALGOS {
            paths.push(format!("points/{i}/measured_s/{algo}"));
            paths.push(format!("points/{i}/model_s/{algo}"));
        }
        for key in ["hierarchical", "in_switch", "auto"] {
            paths.push(format!("points/{i}/speedup_vs_ring/{key}"));
        }
    }
    let path_refs: Vec<&str> = paths.iter().map(String::as_str).collect();
    assert_paths(&j, &path_refs);
    let parsed = Json::parse(&j.to_string_pretty()).expect("BENCH_planner must parse");
    assert_eq!(parsed, j);
    // the gate fields carry the types the CI gate reads
    assert!(j
        .get("gates")
        .unwrap()
        .get("hierarchical_beats_strided_ring")
        .unwrap()
        .as_bool()
        .is_some());
    assert!(
        j.get("gates").unwrap().get("worst_inswitch_err").unwrap().as_f64().unwrap() >= 0.0
    );
}
