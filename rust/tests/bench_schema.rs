// Golden-schema tests for the CI benchmark artifacts
// (`BENCH_scaling.json` from `smartnic scale`, `BENCH_planner.json` from
// `smartnic plan`, `BENCH_engine.json` from `smartnic engine-bench`,
// `BENCH_cluster.json` from `smartnic cluster-trace`,
// `BENCH_collectives.json` from `smartnic collectives`,
// `BENCH_tenancy.json` from `smartnic tenancy`): the exact key
// structure is pinned here and every document must survive a parse
// round-trip, so the artifact shape cannot drift without a test failure.
//
// The schemas themselves (field meanings, units, pass/fail gates) are
// documented in `docs/BENCHMARKS.md`; every key path asserted below must
// appear there, and every schema change must update BOTH this file and
// that document — the cross-reference is deliberate so docs and tests
// cannot drift silently.

use ai_smartnic::experiments::{cluster_trace, collectives, engine_bench, planner, scaling, tenancy};
use ai_smartnic::util::json::Json;

/// Assert that every `/`-separated key path resolves in `doc`; a leading
/// `0` element indexes into an array.
fn assert_paths(doc: &Json, paths: &[&str]) {
    for path in paths {
        let mut cur = doc;
        for part in path.split('/') {
            cur = if let Ok(i) = part.parse::<usize>() {
                cur.idx(i)
                    .unwrap_or_else(|| panic!("missing array index '{part}' in '{path}'"))
            } else {
                cur.get(part)
                    .unwrap_or_else(|| panic!("missing key '{part}' in '{path}'"))
            };
        }
    }
}

#[test]
fn bench_scaling_schema_is_pinned() {
    let cfg = scaling::ScalingConfig {
        nodes: vec![8],
        leaves: 4,
        ..scaling::ScalingConfig::default()
    };
    let sweep = scaling::run_sweep(&cfg);
    let oversub = scaling::run_oversub(&cfg);
    assert!(!oversub.is_empty(), "8 nodes on 4 leaves must produce oversub points");
    let j = scaling::to_json(&cfg, &sweep, &oversub);
    assert_paths(
        &j,
        &[
            "config/batch",
            "config/leaves",
            "config/oversubscription",
            "config/validate_tol",
            "sweep/0/nodes",
            "sweep/0/model_s/baseline",
            "sweep/0/model_s/smartnic",
            "sweep/0/model_s/smartnic+bfp",
            "sweep/0/unified_s/baseline",
            "sweep/0/unified_s/smartnic",
            "sweep/0/unified_s/smartnic+bfp",
            "sweep/0/rel_err/baseline",
            "sweep/0/speedup_vs_baseline/model_nic",
            "sweep/0/speedup_vs_baseline/model_bfp",
            "sweep/0/speedup_vs_baseline/unified_nic",
            "sweep/0/speedup_vs_baseline/unified_bfp",
            "oversubscription_penalty/0/nodes",
            "oversubscription_penalty/0/scheme",
            "oversubscription_penalty/0/flat_ar_s",
            "oversubscription_penalty/0/spanning_ar_s",
            "oversubscription_penalty/0/penalty",
        ],
    );
    // round-trip: the writer's output parses back to the same document
    let parsed = Json::parse(&j.to_string_pretty()).expect("BENCH_scaling must parse");
    assert_eq!(parsed, j);
    // and numeric leaves stay numeric
    assert!(j.get("sweep").unwrap().idx(0).unwrap().get("nodes").unwrap().as_usize() == Some(8));
}

#[test]
fn bench_planner_schema_is_pinned() {
    let cfg = planner::PlannerConfig {
        nodes: vec![6],
        ..planner::PlannerConfig::default()
    };
    let points = planner::run(&cfg);
    assert_eq!(points.len(), 2, "contiguous + strided");
    let j = planner::to_json(&cfg, &points);
    let mut paths = vec![
        "config/oversubscription".to_string(),
        "config/hidden".to_string(),
        "config/inswitch_tol".to_string(),
        "gates/worst_inswitch_err".to_string(),
        "gates/hierarchical_beats_strided_ring".to_string(),
    ];
    for i in 0..2 {
        for key in ["nodes", "leaves", "placement", "chosen"] {
            paths.push(format!("points/{i}/{key}"));
        }
        for algo in planner::ALGOS {
            paths.push(format!("points/{i}/measured_s/{algo}"));
            paths.push(format!("points/{i}/model_s/{algo}"));
        }
        for key in ["hierarchical", "in_switch", "auto"] {
            paths.push(format!("points/{i}/speedup_vs_ring/{key}"));
        }
    }
    let path_refs: Vec<&str> = paths.iter().map(String::as_str).collect();
    assert_paths(&j, &path_refs);
    let parsed = Json::parse(&j.to_string_pretty()).expect("BENCH_planner must parse");
    assert_eq!(parsed, j);
    // the gate fields carry the types the CI gate reads
    assert!(j
        .get("gates")
        .unwrap()
        .get("hierarchical_beats_strided_ring")
        .unwrap()
        .as_bool()
        .is_some());
    assert!(
        j.get("gates").unwrap().get("worst_inswitch_err").unwrap().as_f64().unwrap() >= 0.0
    );
}

#[test]
fn bench_engine_schema_is_pinned() {
    let cfg = engine_bench::EngineBenchConfig {
        nodes: vec![8],
        baseline_nodes: vec![8],
        threads: vec![1, 2],
        scaling_nodes: vec![8],
        max_events: 500,
        oversubscription: 4.0,
        hidden: 128,
    };
    let points = engine_bench::run(&cfg);
    assert_eq!(points.len(), engine_bench::ALGOS.len(), "one point per plan family");
    let scaling = engine_bench::run_scaling(&cfg);
    assert_eq!(scaling.len(), 1 + cfg.threads.len(), "typed reference + one row per thread");
    let j = engine_bench::to_json(&cfg, &points, &scaling);
    let mut paths = vec![
        "config/hidden".to_string(),
        "config/oversubscription".to_string(),
        "config/speedup_gate".to_string(),
        "config/gate_nodes".to_string(),
        "config/virtual_time_tol".to_string(),
        "config/threads".to_string(),
        "config/scaling_nodes".to_string(),
        "config/max_events".to_string(),
        "config/parallel_speedup_gate".to_string(),
        "config/parallel_speedup_floor".to_string(),
        "config/parallel_gate_nodes".to_string(),
        "config/parallel_gate_threads".to_string(),
        "config/checked_overhead_tol".to_string(),
        "gates/ring_gate_speedup".to_string(),
        "gates/speedup_pass".to_string(),
        "gates/worst_virtual_err".to_string(),
        "gates/parallel_worst_virtual_err".to_string(),
        "gates/checked_worst_virtual_err".to_string(),
        "gates/checked_worst_overhead".to_string(),
        "gates/checked_overhead_pass".to_string(),
        "gates/checked_violations".to_string(),
        "gates/parallel_scaling_speedup".to_string(),
        "gates/parallel_scaling_pass".to_string(),
        "gates/parallel_scaling_floor_pass".to_string(),
        "gates/max_nodes_completed".to_string(),
        "gates/scaling_max_nodes_completed".to_string(),
    ];
    for i in 0..points.len() {
        for key in [
            "nodes",
            "algo",
            "virtual_s",
            "events",
            "peak_queue_depth",
            "wall_s",
            "events_per_sec",
            "baseline",
            "parallel",
            "checked",
        ] {
            paths.push(format!("points/{i}/{key}"));
        }
        // this tiny sweep baselines every point, so the baseline object
        // must be populated, not Null
        for key in ["wall_s", "events_per_sec", "speedup", "virtual_err"] {
            paths.push(format!("points/{i}/baseline/{key}"));
        }
    }
    // the NIC ring is row 0 and carries one parallel row per configured
    // thread count
    for i in 0..cfg.threads.len() {
        for key in ["threads", "wall_s", "events_per_sec", "virtual_err", "imbalance"] {
            paths.push(format!("points/0/parallel/{i}/{key}"));
        }
        // ... and one audited (checked-executive) row per thread count
        for key in ["threads", "wall_s", "events_per_sec", "virtual_err", "overhead", "violations"]
        {
            paths.push(format!("points/0/checked/{i}/{key}"));
        }
    }
    for i in 0..scaling.len() {
        for key in
            ["nodes", "threads", "virtual_s", "events", "wall_s", "events_per_sec", "imbalance"]
        {
            paths.push(format!("scaling/{i}/{key}"));
        }
    }
    let path_refs: Vec<&str> = paths.iter().map(String::as_str).collect();
    assert_paths(&j, &path_refs);
    let parsed = Json::parse(&j.to_string_pretty()).expect("BENCH_engine must parse");
    assert_eq!(parsed, j);
    // the gate fields carry the types the CI gate reads: an 8-node sweep
    // has no 512-node ring point and no 16384-node scaling pair, so both
    // speedup gates must be Null (not a vacuous PASS), while parity and
    // completion stay populated
    let gates = j.get("gates").unwrap();
    assert_eq!(gates.get("ring_gate_speedup"), Some(&Json::Null));
    assert_eq!(gates.get("speedup_pass"), Some(&Json::Null));
    assert_eq!(gates.get("parallel_scaling_speedup"), Some(&Json::Null));
    assert_eq!(gates.get("parallel_scaling_pass"), Some(&Json::Null));
    assert_eq!(gates.get("parallel_scaling_floor_pass"), Some(&Json::Null));
    assert!(gates.get("worst_virtual_err").unwrap().as_f64().unwrap() <= 1e-9);
    assert!(gates.get("parallel_worst_virtual_err").unwrap().as_f64().unwrap() <= 1e-9);
    // the audited rows exist at any sweep size: violations must be zero
    // and the overhead gate must carry a boolean verdict, not Null
    assert!(gates.get("checked_worst_virtual_err").unwrap().as_f64().unwrap() <= 1e-9);
    assert_eq!(gates.get("checked_violations").unwrap().as_usize(), Some(0));
    assert!(gates.get("checked_overhead_pass").unwrap().as_bool().is_some());
    assert_eq!(gates.get("max_nodes_completed").unwrap().as_usize(), Some(8));
    assert_eq!(gates.get("scaling_max_nodes_completed").unwrap().as_usize(), Some(8));
}

#[test]
fn bench_collectives_schema_is_pinned() {
    let cfg = collectives::CollectivesConfig {
        nodes: vec![6],
        hidden: 256,
        ..collectives::CollectivesConfig::default()
    };
    let study = collectives::run(&cfg);
    assert!(!study.points.is_empty(), "a 6-node sweep must produce cells");
    assert_eq!(study.scenarios.len(), 2, "moe + weight-broadcast");
    let j = collectives::to_json(&cfg, &study);
    let mut paths = vec![
        "config/oversubscription".to_string(),
        "config/hidden".to_string(),
        "config/parity_tol".to_string(),
        "gates/worst_gated_parity".to_string(),
        "gates/worst_alltoall_spine_err".to_string(),
        "gates/mcast_beats_binomial".to_string(),
        "gates/audit_clean".to_string(),
    ];
    for i in 0..study.points.len() {
        for key in [
            "kind",
            "nodes",
            "topology",
            "plan",
            "model_s",
            "measured_s",
            "parity_err",
            "chosen",
            "gated",
        ] {
            paths.push(format!("points/{i}/{key}"));
        }
    }
    for i in 0..study.scenarios.len() {
        for key in ["name", "nodes", "duration_s", "mean_collective_s", "collectives"] {
            paths.push(format!("scenarios/{i}/{key}"));
        }
    }
    let path_refs: Vec<&str> = paths.iter().map(String::as_str).collect();
    assert_paths(&j, &path_refs);
    let parsed = Json::parse(&j.to_string_pretty()).expect("BENCH_collectives must parse");
    assert_eq!(parsed, j);
    // the gate fields carry the types the CI gate reads: 6 is a pinned
    // node count, so parity is populated (and the all-to-all spine
    // deviation is reported alongside it) ...
    let gates = j.get("gates").unwrap();
    assert!(gates.get("worst_gated_parity").unwrap().as_f64().unwrap() >= 0.0);
    assert!(gates.get("worst_alltoall_spine_err").unwrap().as_f64().unwrap() >= 0.0);
    // ... while null-not-vacuous holds for the gates this sweep cannot
    // decide: no N >= 32 broadcast pair, no audit on the typed engine
    assert_eq!(gates.get("mcast_beats_binomial"), Some(&Json::Null));
    assert_eq!(gates.get("audit_clean"), Some(&Json::Null));
    // every cell names a real plan family and carries a boolean gate flag
    for i in 0..study.points.len() {
        let p = j.get("points").unwrap().idx(i).unwrap();
        assert!(p.get("measured_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(p.get("gated").unwrap().as_bool().is_some());
    }
}

#[test]
fn bench_tenancy_schema_is_pinned() {
    // a 1x1x1 grid containing the default point (max tenants, scale 1.0,
    // rate 0): the knee/solo/audit/determinism gates are all decidable
    let cfg = tenancy::TenancyConfig {
        tenant_counts: vec![1],
        table_scales: vec![1.0],
        pause_rates: vec![0.0],
        ..tenancy::TenancyConfig::default()
    };
    let points = tenancy::run(&cfg);
    assert_eq!(points.len(), 1, "one grid point");
    let g = tenancy::gates(&cfg, &points);
    let j = tenancy::to_json(&cfg, &points, &g);
    let mut paths = vec![
        "config/leaves".to_string(),
        "config/nodes_per_leaf".to_string(),
        "config/oversubscription".to_string(),
        "config/hidden".to_string(),
        "config/base_table_bytes".to_string(),
        "config/pause_window_s".to_string(),
        "config/tenant_counts".to_string(),
        "config/table_scales".to_string(),
        "config/pause_rates".to_string(),
        "gates/knee_default".to_string(),
        "gates/solo_inswitch_wins".to_string(),
        "gates/pause_collapses_knee".to_string(),
        "gates/audited_clean".to_string(),
        "gates/deterministic".to_string(),
        "gates/pass".to_string(),
    ];
    for key in [
        "tenants",
        "table_scale",
        "table_bytes",
        "pause_rate",
        "pfc_duty",
        "outcomes",
        "knee",
        "admitted",
        "evicted",
        "fallback",
        "table_evictions",
        "makespan_s",
        "mean_ar_first_s",
        "mean_ar_last_s",
    ] {
        paths.push(format!("points/0/{key}"));
    }
    let path_refs: Vec<&str> = paths.iter().map(String::as_str).collect();
    assert_paths(&j, &path_refs);
    let parsed = Json::parse(&j.to_string_pretty()).expect("BENCH_tenancy must parse");
    assert_eq!(parsed, j);
    // the gate fields carry the types the CI gate reads: the decidable
    // gates are booleans, while a sweep with no pause rate > 0 cannot
    // decide the pause gate — Null, never a vacuous PASS
    let gates = j.get("gates").unwrap();
    assert!(gates.get("solo_inswitch_wins").unwrap().as_bool().is_some());
    assert!(gates.get("audited_clean").unwrap().as_bool().is_some());
    assert!(gates.get("deterministic").unwrap().as_bool().is_some());
    assert_eq!(gates.get("pause_collapses_knee"), Some(&Json::Null));
    // ... and a solo grid has no knee, so the headline gate cannot pass
    assert_eq!(gates.get("pass").unwrap().as_bool(), Some(false));
    // per-point leaves keep the types the plots read
    let p = j.get("points").unwrap().idx(0).unwrap();
    assert_eq!(p.get("tenants").unwrap().as_usize(), Some(1));
    assert!(p.get("makespan_s").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(p.get("outcomes").unwrap().idx(0).unwrap().as_str(), Some("admitted"));

    // null-not-vacuous for the rest: a grid missing the (max tenants,
    // scale 1.0, rate 0) point cannot decide the knee or solo gates
    let cfg2 = tenancy::TenancyConfig {
        tenant_counts: vec![2],
        table_scales: vec![4.0],
        pause_rates: vec![0.0],
        ..tenancy::TenancyConfig::default()
    };
    let points2 = tenancy::run(&cfg2);
    let g2 = tenancy::gates(&cfg2, &points2);
    let j2 = tenancy::to_json(&cfg2, &points2, &g2);
    let gates2 = j2.get("gates").unwrap();
    for key in ["knee_default", "solo_inswitch_wins", "pause_collapses_knee"] {
        assert_eq!(gates2.get(key), Some(&Json::Null), "gate '{key}' must be Null, not vacuous");
    }
    assert_eq!(gates2.get("pass").unwrap().as_bool(), Some(false));
}

#[test]
fn bench_cluster_schema_is_pinned() {
    let cfg = cluster_trace::ClusterTraceConfig {
        nodes: 16,
        leaves: 4,
        jobs: 10,
        max_gang: 8,
        max_iters: 3,
        hidden: 64,
        batch_per_node: 8,
        mean_interarrival: 0.01,
        failures: 1,
        restart_delay: 0.01,
        repair_delay: 0.05,
        ..cluster_trace::ClusterTraceConfig::default()
    };
    let points = cluster_trace::run(&cfg);
    assert_eq!(points.len(), 4, "one row per placement policy");
    let audit = cluster_trace::run_audited(&cfg);
    let determinism = cluster_trace::check_determinism(&cfg, &points);
    let j = cluster_trace::to_json(&cfg, &points, Some(&audit), determinism);
    let mut paths = vec![
        "config/nodes".to_string(),
        "config/leaves".to_string(),
        "config/oversubscription".to_string(),
        "config/jobs".to_string(),
        "config/seed".to_string(),
        "config/mean_interarrival".to_string(),
        "config/min_gang".to_string(),
        "config/max_gang".to_string(),
        "config/max_iters".to_string(),
        "config/layers".to_string(),
        "config/hidden".to_string(),
        "config/elastic_fraction".to_string(),
        "config/failures".to_string(),
        "config/threads".to_string(),
        "config/frag_gap_min".to_string(),
        "config/frag_gap_target".to_string(),
        "gates/frag_jct_gap".to_string(),
        "gates/frag_gap_pass".to_string(),
        "gates/frag_gap_target_pass".to_string(),
        "gates/audit_violations".to_string(),
        "gates/audit_events_checked".to_string(),
        "gates/audit_pass".to_string(),
        "gates/determinism_pass".to_string(),
        "gates/total_preemptions".to_string(),
        "gates/all_jobs_completed".to_string(),
    ];
    for i in 0..points.len() {
        for key in [
            "policy",
            "jobs",
            "p50_jct",
            "p99_jct",
            "mean_jct",
            "p50_wait",
            "p99_wait",
            "makespan",
            "node_util",
            "eth_util",
            "frag_jobs",
            "preemptions",
            "restarts",
            "aborted_collectives",
            "events",
            "peak_queue_depth",
            "wall_s",
        ] {
            paths.push(format!("policies/{i}/{key}"));
        }
    }
    let path_refs: Vec<&str> = paths.iter().map(String::as_str).collect();
    assert_paths(&j, &path_refs);
    let parsed = Json::parse(&j.to_string_pretty()).expect("BENCH_cluster must parse");
    assert_eq!(parsed, j);
    // the gate fields carry the types the CI gate reads
    let gates = j.get("gates").unwrap();
    assert_eq!(gates.get("audit_violations").unwrap().as_usize(), Some(0));
    assert_eq!(gates.get("audit_pass").unwrap().as_bool(), Some(true));
    assert_eq!(gates.get("determinism_pass").unwrap().as_bool(), Some(true));
    assert!(gates.get("frag_jct_gap").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(gates.get("all_jobs_completed").unwrap().as_bool(), Some(true));
    // null-not-vacuous: a sweep missing the scatter point cannot compute
    // the fragmentation gap, and a run without the audited / determinism
    // passes must emit Null, never a vacuous PASS
    let sliced: Vec<_> =
        points.iter().filter(|p| p.policy != "scatter").cloned().collect();
    let j2 = cluster_trace::to_json(&cfg, &sliced, None, None);
    let gates2 = j2.get("gates").unwrap();
    for key in [
        "frag_jct_gap",
        "frag_gap_pass",
        "frag_gap_target_pass",
        "audit_violations",
        "audit_events_checked",
        "audit_pass",
        "determinism_pass",
    ] {
        assert_eq!(gates2.get(key), Some(&Json::Null), "gate '{key}' must be Null, not vacuous");
    }
}
