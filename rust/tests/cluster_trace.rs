// Property suite for the trace-driven gang scheduler
// (`cluster::sched`).  Every run emits an allocation journal
// (`TraceOutput::log`, in commit order); these tests replay that journal
// over an independent ownership model and check the scheduler's core
// invariants on every committed decision, across seeds and policies:
//
// * no double-allocation — a `Place` only ever commits free, up nodes;
// * gang atomicity — a job holds zero nodes at `Place` time and the
//   whole gang commits in one journal entry (never a partial gang);
// * release honesty — a `Release` only returns nodes the job owns;
// * conservation — every job in the trace ends with exactly one result
//   and a completion no earlier than its arrival;
// * contiguous-preferred — a frag-allowed *initial* placement is only
//   fragmented when no contiguous free+up hole could have held the gang
//   (elastic in-place regrows are exempt: they extend the current block
//   rather than migrate, by design).

use ai_smartnic::cluster::{
    run_trace, synth_trace, AllocEvent, AllocKind, EngineKind, Policy, Topology, TraceGenConfig,
    TraceOutput, TraceSpec,
};
use ai_smartnic::sysconfig::SystemParams;

const SEEDS: [u64; 4] = [1, 7, 23, 104729];

fn small_trace(policy: Policy, seed: u64, failures: usize) -> TraceSpec {
    synth_trace(
        SystemParams::smartnic_40g(),
        Topology::leaf_spine(4, 4, 4.0),
        policy,
        &TraceGenConfig {
            jobs: 14,
            seed,
            mean_interarrival: 0.01,
            min_gang: 2,
            max_gang: 8,
            max_iters: 3,
            layers: 2,
            hidden: 64,
            batch_per_node: 8,
            elastic_fraction: 0.4,
            failures,
            restart_delay: 0.01,
            repair_delay: 0.05,
        },
    )
}

/// Independent replay model: node -> owning job, node -> down.
struct Model {
    owner: Vec<Option<usize>>,
    down: Vec<bool>,
}

impl Model {
    fn new(nodes: usize) -> Self {
        Self { owner: vec![None; nodes], down: vec![false; nodes] }
    }

    /// Longest run of consecutive free, up nodes.
    fn max_free_run(&self) -> usize {
        let mut best = 0;
        let mut run = 0;
        for i in 0..self.owner.len() {
            if self.owner[i].is_none() && !self.down[i] {
                run += 1;
                best = best.max(run);
            } else {
                run = 0;
            }
        }
        best
    }

    fn held_by(&self, job: usize) -> usize {
        self.owner.iter().filter(|o| **o == Some(job)).count()
    }
}

fn contiguous(nodes: &[usize]) -> bool {
    nodes.windows(2).all(|w| w[1] == w[0] + 1)
}

/// Replay the allocation journal, asserting the placement invariants on
/// every entry.  `check_frag_minimality` additionally asserts the
/// contiguous-preferred property on fragmented initial placements.
fn replay(out: &TraceOutput, label: &str, check_frag_minimality: bool) -> Model {
    let mut m = Model::new(out.nodes);
    let mut last_t = f64::NEG_INFINITY;
    let mut prev: Option<&AllocEvent> = None;
    for ev in &out.log {
        assert!(
            ev.t >= last_t,
            "{label}: journal out of commit order at t={} (prev {last_t})",
            ev.t
        );
        last_t = ev.t;
        match ev.kind {
            AllocKind::Place { frag } => {
                assert!(!ev.nodes.is_empty(), "{label}: empty gang placed");
                assert!(
                    ev.nodes.windows(2).all(|w| w[1] > w[0]),
                    "{label}: placed nodes not strictly ascending: {:?}",
                    ev.nodes
                );
                assert_eq!(
                    m.held_by(ev.job),
                    0,
                    "{label}: job {} placed while still holding nodes (partial gang)",
                    ev.job
                );
                assert_eq!(
                    frag,
                    !contiguous(&ev.nodes),
                    "{label}: frag flag disagrees with the node set {:?}",
                    ev.nodes
                );
                // An elastic in-place regrow is journalled as a same-time
                // Release/Place pair for the same job; only *initial*
                // placements must prefer a contiguous hole.
                let elastic_replace = prev.is_some_and(|p| {
                    p.kind == AllocKind::Release && p.job == ev.job && p.t == ev.t
                });
                if check_frag_minimality && frag && !elastic_replace {
                    assert!(
                        m.max_free_run() < ev.nodes.len(),
                        "{label}: fragmented a {}-gang although a contiguous \
                         free run of >= {} nodes existed",
                        ev.nodes.len(),
                        ev.nodes.len()
                    );
                }
                for &n in &ev.nodes {
                    assert!(n < out.nodes, "{label}: node {n} out of range");
                    assert!(
                        m.owner[n].is_none(),
                        "{label}: double-allocation of node {n} (held by job {:?}, \
                         placed for job {})",
                        m.owner[n],
                        ev.job
                    );
                    assert!(!m.down[n], "{label}: down node {n} handed to job {}", ev.job);
                    m.owner[n] = Some(ev.job);
                }
            }
            AllocKind::Release => {
                for &n in &ev.nodes {
                    assert_eq!(
                        m.owner[n],
                        Some(ev.job),
                        "{label}: job {} released node {n} it does not own",
                        ev.job
                    );
                    m.owner[n] = None;
                }
            }
            AllocKind::NodeDown => {
                for &n in &ev.nodes {
                    m.down[n] = true;
                }
            }
            AllocKind::NodeUp => {
                for &n in &ev.nodes {
                    m.down[n] = false;
                }
            }
        }
        prev = Some(ev);
    }
    m
}

fn assert_conserved(spec: &TraceSpec, out: &TraceOutput, label: &str) {
    assert_eq!(
        out.jobs.len(),
        spec.jobs.len(),
        "{label}: arrived {} jobs but only {} results",
        spec.jobs.len(),
        out.jobs.len()
    );
    for (tj, r) in spec.jobs.iter().zip(&out.jobs) {
        assert_eq!(tj.name, r.name, "{label}: result order diverged from the trace");
        assert!(
            r.completed >= tj.arrival,
            "{label}: job '{}' completed at {} before its arrival {}",
            r.name,
            r.completed,
            tj.arrival
        );
        assert!(r.jct >= 0.0 && r.jct.is_finite(), "{label}: bad JCT for '{}'", r.name);
        assert!(r.iters >= 1, "{label}: job '{}' finished zero iterations", r.name);
    }
}

#[test]
fn no_double_allocation_across_policies_and_seeds() {
    for policy in Policy::ALL {
        for seed in SEEDS {
            let spec = small_trace(policy, seed, 2);
            let out = run_trace(&spec, EngineKind::Typed);
            let label = format!("{}/seed{seed}", policy.name());
            let end = replay(&out, &label, false);
            // at quiescence everything must be back in the free pool
            for (n, o) in end.owner.iter().enumerate() {
                assert!(o.is_none(), "{label}: node {n} still held by {o:?} at quiescence");
            }
        }
    }
}

#[test]
fn gang_placement_is_all_or_none() {
    for seed in SEEDS {
        let spec = small_trace(Policy::FragAllowed, seed, 2);
        let out = run_trace(&spec, EngineKind::Typed);
        // `replay` asserts the job holds zero nodes at each Place, so a
        // gang can never accrete piecewise; here we additionally pin that
        // every first placement covers the trace's full gang demand.
        replay(&out, &format!("atomicity/seed{seed}"), false);
        // result order == trace order == job id order (asserted by
        // `assert_conserved` elsewhere), so the index is the journal id
        for (jid, tj) in spec.jobs.iter().enumerate() {
            let first = out
                .log
                .iter()
                .find(|e| matches!(e.kind, AllocKind::Place { .. }) && e.job == jid)
                .unwrap_or_else(|| panic!("job '{}' never placed", tj.name));
            assert!(
                !first.nodes.is_empty() && first.nodes.len() <= out.nodes,
                "job '{}' first gang of {} nodes is out of range",
                tj.name,
                first.nodes.len()
            );
        }
    }
}

#[test]
fn every_arrived_job_completes() {
    for policy in Policy::ALL {
        for seed in SEEDS {
            let spec = small_trace(policy, seed, 2);
            let out = run_trace(&spec, EngineKind::Typed);
            assert_conserved(&spec, &out, &format!("{}/seed{seed}", policy.name()));
        }
    }
}

#[test]
fn frag_allowed_prefers_contiguous_holes() {
    for seed in SEEDS {
        let spec = small_trace(Policy::FragAllowed, seed, 2);
        let out = run_trace(&spec, EngineKind::Typed);
        replay(&out, &format!("frag-minimality/seed{seed}"), true);
    }
}

#[test]
fn contiguous_policies_never_journal_a_fragmented_place() {
    for policy in [Policy::FirstFit, Policy::BestFit] {
        for seed in SEEDS {
            let spec = small_trace(policy, seed, 2);
            let out = run_trace(&spec, EngineKind::Typed);
            let label = format!("{}/seed{seed}", policy.name());
            for ev in &out.log {
                if let AllocKind::Place { frag } = ev.kind {
                    assert!(!frag, "{label}: fragmented place journalled: {:?}", ev.nodes);
                    assert!(contiguous(&ev.nodes), "{label}: non-contiguous gang {:?}", ev.nodes);
                }
            }
        }
    }
}

#[test]
fn failures_keep_the_journal_consistent() {
    // heavier churn: more failures than the default, all invariants hold
    // and the run still drains (run_trace panics on a deadlocked trace).
    for seed in SEEDS {
        let spec = small_trace(Policy::FragAllowed, seed, 5);
        let out = run_trace(&spec, EngineKind::Typed);
        let label = format!("churn/seed{seed}");
        replay(&out, &label, false);
        assert_conserved(&spec, &out, &label);
        let preempts: u32 = out.jobs.iter().map(|j| j.preemptions).sum();
        let restarts: u32 = out.jobs.iter().map(|j| j.restarts).sum();
        assert_eq!(
            preempts, restarts,
            "{label}: every preemption must pair with exactly one restart"
        );
    }
}
