// Integration tests for the unified cluster engine refactor:
//  * data-path property tests: every all-reduce algorithm == serial sum
//    for random non-power-of-two node counts and ragged-tail gradients;
//  * exact single-ring parity between the event engine and the serialized
//    chunk-level NIC simulation (with and without fault injection);
//  * determinism: identical specs -> identical traces;
//  * multi-tenant contention and cluster-wide fault propagation;
//  * per-layer algorithm selection.

use ai_smartnic::analytic::model::SystemKind;
use ai_smartnic::bfp::BfpCodec;
use ai_smartnic::cluster::{run_scenario, ClusterSpec, CollectiveAlgo, JobSpec};
use ai_smartnic::collective::algorithms::{binomial_allreduce, rabenseifner_allreduce};
use ai_smartnic::collective::data::{ring_allreduce, serial_sum};
use ai_smartnic::collective::Scheme;
use ai_smartnic::nic::{simulate_ring_allreduce, NicConfig};
use ai_smartnic::prop::{forall, gens};
use ai_smartnic::sysconfig::{ClusterFaults, SystemParams, Workload};
use ai_smartnic::util::rng::Rng;

fn make_bufs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
        .collect()
}

#[test]
fn prop_all_algorithms_match_serial_on_nonpow2_ragged_shapes() {
    forall(
        &gens::pair(gens::usize_in(3..=12), gens::usize_in(1..=400)),
        60,
        |&(n0, len0)| {
            // force a non-power-of-two worker count and a ragged tail
            // (len not divisible by n, so the last ring chunk is short)
            let n = if n0.is_power_of_two() { n0 + 1 } else { n0 };
            let len = if len0 % n == 0 { len0 + 1 } else { len0 };
            let want = serial_sum(&make_bufs(n, len, (n * 131 + len) as u64));
            let close = |bufs: &[Vec<f32>]| {
                bufs.iter().all(|b| {
                    b.iter()
                        .zip(&want)
                        .all(|(g, w)| (g - w).abs() <= w.abs() * 1e-5 + 1e-5)
                })
            };
            let mut a = make_bufs(n, len, (n * 131 + len) as u64);
            binomial_allreduce(&mut a);
            let mut b = make_bufs(n, len, (n * 131 + len) as u64);
            rabenseifner_allreduce(&mut b);
            let mut c = make_bufs(n, len, (n * 131 + len) as u64);
            ring_allreduce(&mut c, None);
            close(&a) && close(&b) && close(&c)
        },
    );
}

fn one_layer_job(
    sys: SystemParams,
    n: usize,
    hidden: usize,
    bfp: bool,
    faults: ClusterFaults,
) -> f64 {
    let w = Workload {
        layers: 1,
        hidden,
        batch_per_node: 64,
    };
    let spec = ClusterSpec::new(sys, n).with_faults(faults).with_job(JobSpec::new(
        "ring",
        SystemKind::SmartNic { bfp },
        w,
        (0..n).collect(),
    ));
    let out = run_scenario(&spec);
    assert_eq!(out.jobs[0].ar_count, 1);
    out.jobs[0].mean_ar
}

#[test]
fn single_ring_matches_serialized_nic_des_exactly() {
    // an uncontended event-driven ring performs the identical serve/max
    // arithmetic as nic::simulate_ring_allreduce — the timings must agree
    // to float precision, across node counts and compression
    let sys = SystemParams::smartnic_40g();
    for n in [2usize, 3, 4, 6, 8] {
        for bfp in [false, true] {
            let hidden = 512;
            let cfg = NicConfig::new(sys, if bfp { Some(BfpCodec::bfp16()) } else { None });
            let serialized = simulate_ring_allreduce(&cfg, n, hidden * hidden).t_total;
            let unified = one_layer_job(sys, n, hidden, bfp, ClusterFaults::none());
            let err = (serialized - unified).abs() / serialized;
            assert!(
                err < 1e-9,
                "n={n} bfp={bfp}: serialized {serialized} unified {unified}"
            );
        }
    }
}

#[test]
fn single_ring_under_faults_is_bounded_by_the_serialized_path() {
    // the unified fabric models a degraded link on *both* directions (the
    // victim's Tx uplink and the switch egress toward it), while the
    // serialized NIC DES only scales the Tx side.  The extra ingress
    // contention can only delay FIFO events — and because the two slow
    // stages sit in series at the same rate, the gap stays a pipeline
    // transient, not a blow-up.
    let sys = SystemParams::smartnic_40g();
    let hidden = 1024;
    let cfg = NicConfig::new(sys, None)
        .with_degraded_link(2, 0.25)
        .with_straggler(4, 0.5);
    let serialized = simulate_ring_allreduce(&cfg, 6, hidden * hidden).t_total;
    let faults = ClusterFaults::none()
        .with_degraded_link(2, 0.25)
        .with_straggler(4, 0.5);
    let unified = one_layer_job(sys, 6, hidden, false, faults);
    assert!(
        unified >= serialized * (1.0 - 1e-9),
        "serialized {serialized} unified {unified}"
    );
    assert!(
        unified <= serialized * 1.5,
        "serialized {serialized} unified {unified}"
    );
}

fn two_job_spec(batch: usize) -> ClusterSpec {
    let sys = SystemParams::smartnic_40g();
    let w = Workload {
        layers: 8,
        hidden: 1024,
        batch_per_node: batch,
    };
    let kind = SystemKind::SmartNic { bfp: false };
    ClusterSpec::new(sys, 4)
        .with_job(JobSpec::new("j0", kind, w, (0..4).collect()))
        .with_job(JobSpec::new("j1", kind, w, (0..4).collect()))
}

#[test]
fn unified_engine_is_deterministic() {
    let a = run_scenario(&two_job_spec(64));
    let b = run_scenario(&two_job_spec(64));
    assert_eq!(a.events, b.events);
    assert_eq!(a.trace.spans, b.trace.spans);
    for (ja, jb) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(ja.t_end, jb.t_end);
        assert_eq!(ja.mean_ar, jb.mean_ar);
    }
}

#[test]
fn multi_tenant_jobs_contend_for_the_fabric() {
    let sys = SystemParams::smartnic_40g();
    let w = Workload::paper_mlp(448);
    let kind = SystemKind::SmartNic { bfp: false };
    let solo = run_scenario(
        &ClusterSpec::new(sys, 6).with_job(JobSpec::new("solo", kind, w, (0..6).collect())),
    );
    let pair = run_scenario(
        &ClusterSpec::new(sys, 6)
            .with_job(JobSpec::new("j0", kind, w, (0..6).collect()))
            .with_job(JobSpec::new("j1", kind, w, (0..6).collect())),
    );
    let t_solo = solo.jobs[0].duration;
    for j in &pair.jobs {
        assert!(
            j.duration > t_solo * 1.05,
            "{}: {} not slower than isolated {}",
            j.name,
            j.duration,
            t_solo
        );
        assert!(
            j.duration < t_solo * 2.5,
            "{}: {} implausibly slow vs isolated {}",
            j.name,
            j.duration,
            t_solo
        );
    }
    // the fabric's links are busier than with one tenant
    assert!(pair.eth_util > solo.eth_util * 1.02);
}

#[test]
fn straggler_degrades_every_job() {
    let healthy = run_scenario(&two_job_spec(448));
    let faulty = run_scenario(
        &two_job_spec(448).with_faults(ClusterFaults::none().with_straggler(1, 0.2)),
    );
    for (h, f) in healthy.jobs.iter().zip(&faulty.jobs) {
        assert!(
            f.duration > h.duration * 1.1,
            "{}: faulty {} vs healthy {}",
            f.name,
            f.duration,
            h.duration
        );
    }
}

#[test]
fn per_layer_algorithm_selection_runs_and_costs_more_than_ring() {
    let sys = SystemParams::smartnic_40g();
    let w = Workload {
        layers: 4,
        hidden: 1024,
        batch_per_node: 128,
    };
    let kind = SystemKind::SmartNic { bfp: false };
    let ring_only = run_scenario(
        &ClusterSpec::new(sys, 4).with_job(JobSpec::new("ring", kind, w, (0..4).collect())),
    );
    let mixed = run_scenario(
        &ClusterSpec::new(sys, 4).with_job(
            JobSpec::new("mixed", kind, w, (0..4).collect()).with_layer_algos(vec![
                CollectiveAlgo::NicRing,
                CollectiveAlgo::NicBinomial,
                CollectiveAlgo::NicRabenseifner,
                CollectiveAlgo::NicRing,
            ]),
        ),
    );
    assert_eq!(mixed.jobs[0].ar_count, 4);
    assert!(mixed.jobs[0].duration.is_finite());
    // binomial moves ~2·lg(n)·R on the wire vs the ring's 2(N-1)/N·R:
    // the mixed schedule cannot be faster than ring-everywhere
    assert!(mixed.jobs[0].duration >= ring_only.jobs[0].duration * 0.999);
}

#[test]
fn host_jobs_share_comm_cores() {
    // two naive-baseline jobs on the same hosts: the shared comm servers
    // serialize their software all-reduces
    let sys = SystemParams::baseline_100g();
    let w = Workload {
        layers: 4,
        hidden: 2048,
        batch_per_node: 448,
    };
    let kind = SystemKind::BaselineNaive { scheme: Scheme::Ring };
    let solo = run_scenario(
        &ClusterSpec::new(sys, 4).with_job(JobSpec::new("solo", kind, w, (0..4).collect())),
    );
    let pair = run_scenario(
        &ClusterSpec::new(sys, 4)
            .with_job(JobSpec::new("j0", kind, w, (0..4).collect()))
            .with_job(JobSpec::new("j1", kind, w, (0..4).collect())),
    );
    for j in &pair.jobs {
        assert!(j.duration > solo.jobs[0].duration);
    }
}

#[test]
fn concurrent_ars_and_wait_accounting() {
    // B=448 raw at 6 nodes: all-reduce latency exceeds per-segment
    // compute, so the trace must show overlapping ARs and nonzero waits
    let sys = SystemParams::smartnic_40g();
    let w = Workload::paper_mlp(448);
    let out = run_scenario(&ClusterSpec::new(sys, 6).with_job(JobSpec::new(
        "j0",
        SystemKind::SmartNic { bfp: false },
        w,
        (0..6).collect(),
    )));
    assert!(out.trace.max_concurrent("ar") >= 2);
    assert!(out.jobs[0].max_inflight >= 2);
    assert!(out.jobs[0].exposed_wait > 0.0);
    // worker lane itself must stay serial even while ARs overlap
    out.trace.check_lane_serial("j0/worker").unwrap();
}
