// Property suite locking down the collective zoo (ISSUE 9): broadcast,
// allgather, reduce-scatter and all-to-all as first-class planned
// collectives next to all-reduce.
//
//  * Movement conservation, per kind and per peer: the round generators
//    and every candidate plan the kind-aware planner prices move each
//    element exactly the required number of times — a broadcast delivers
//    the payload to every non-root exactly once (and never to the root),
//    an allgather hands every rank (n−1) foreign shards, a
//    reduce-scatter folds each element exactly once into its owning
//    rank's shard, and an all-to-all exchanges every ordered pair's
//    private block exactly once.  Movement-style plans fold nothing.
//  * With replication-rate → ∞ and no table pressure, the engine's
//    switch-multicast broadcast converges to
//    `switch_multicast_time_elems` exactly (the closed form's segment
//    pipeline is the executor's), on a flat crossbar, a tapered spine
//    and a partial-leaf placement.
//  * A switch that cannot replicate (no engines, a zero-capacity table,
//    or a table smaller than one segment) degrades to the *identical*
//    host binomial tree — the multicast mirror of the in-switch → ring
//    fallback guard in `rust/tests/planner.rs`.
//  * Every executed kind audits clean under the checked engine: the
//    conservation ledger's per-kind expected-fold counts and the
//    multicast replication ledger both match what the fabric did.

use ai_smartnic::analytic::model::switch_multicast_time_elems;
use ai_smartnic::cluster::collective::{
    all_to_all_rounds, allgather_ring_rounds, broadcast_binomial_rounds,
    reduce_scatter_ring_rounds, Phase, RoundOp,
};
use ai_smartnic::cluster::planner::{self, PlanKind};
use ai_smartnic::cluster::{CollectiveAlgo, CollectiveKind, EngineKind, Topology};
use ai_smartnic::experiments::collectives::{measure_collective, KINDS};
use ai_smartnic::prop::{forall, gens};
use ai_smartnic::sysconfig::{SwitchParams, SystemParams};
use ai_smartnic::util::stats::rel_err;

/// Both placements for a random (leaves, nodes_per_leaf, oversub) shape.
fn shapes(leaves: usize, m: usize, oversub: f64) -> Vec<(Topology, Vec<usize>)> {
    let n = leaves * m;
    let ls = Topology::leaf_spine(leaves, m, oversub);
    vec![
        (Topology::flat(n), (0..n).collect()),
        (ls, ls.contiguous_ranks(n)),
        (ls, ls.strided_ranks(n)),
    ]
}

fn netreduce_sys(radix: usize) -> SystemParams {
    let s = SystemParams::smartnic_40g();
    s.with_switch_reduction(SwitchParams::netreduce(radix, &s.net))
}

/// Per-destination received bytes and op counts of a rounds schedule.
fn receipts(rounds: &[Vec<RoundOp>], n: usize) -> (Vec<f64>, Vec<usize>) {
    let mut bytes = vec![0.0f64; n];
    let mut count = vec![0usize; n];
    for op in rounds.iter().flatten() {
        bytes[op.dst] += op.bytes;
        count[op.dst] += 1;
    }
    (bytes, count)
}

#[test]
fn prop_broadcast_tree_delivers_to_every_nonroot_exactly_once() {
    // the binomial tree hands rank 0's payload to each of the other n−1
    // ranks exactly once, never back to the root, in ⌈log₂ n⌉ rounds —
    // and causally: nobody forwards a payload they do not yet hold
    let s = 4096.0;
    forall(&gens::usize_in(2..=40), 64, |&n| {
        let rounds = broadcast_binomial_rounds(n, s);
        if rounds.len() != (n as f64).log2().ceil() as usize {
            return false;
        }
        if rounds.iter().flatten().any(|op| op.reduce_elems != 0.0) {
            return false;
        }
        let mut holds = vec![false; n];
        holds[0] = true;
        for round in &rounds {
            if round.iter().any(|op| !holds[op.src]) {
                return false;
            }
            for op in round {
                holds[op.dst] = true;
            }
        }
        let (bytes, count) = receipts(&rounds, n);
        holds.iter().all(|h| *h)
            && count[0] == 0
            && (1..n).all(|v| count[v] == 1 && bytes[v] == s)
    });
}

#[test]
fn prop_allgather_ring_hands_every_rank_its_missing_shards() {
    // n−1 rounds; per round every rank forwards exactly one S/n shard to
    // its successor (the full cycle), so each rank accumulates the n−1
    // shards it is missing: (n−1)·S/n received per rank, (n−1)·S total,
    // zero folds
    let s = 4096.0;
    forall(&gens::usize_in(2..=40), 64, |&n| {
        let rounds = allgather_ring_rounds(n, s);
        if rounds.len() != n - 1 {
            return false;
        }
        let shard = s / n as f64;
        for round in &rounds {
            if round.len() != n {
                return false;
            }
            let mut sent = vec![0usize; n];
            for op in round {
                if op.dst != (op.src + 1) % n || op.bytes != shard || op.reduce_elems != 0.0 {
                    return false;
                }
                sent[op.src] += 1;
            }
            if sent.iter().any(|&c| c != 1) {
                return false;
            }
        }
        let want = (n as f64 - 1.0) * shard;
        let (bytes, count) = receipts(&rounds, n);
        (0..n).all(|v| count[v] == n - 1 && (bytes[v] - want).abs() <= want * 1e-12)
    });
}

#[test]
fn prop_reduce_scatter_ring_folds_each_element_once_into_its_owner() {
    // n−1 rounds of S/n shards around the ring, each folding E/n at its
    // destination: every rank performs (n−1)·E/n genuine adds and the
    // schedule totals exactly (n−1)·E — each element reduced once per
    // contributing peer, landing in its owner's shard
    let s = 4096.0;
    let elems = 1024.0;
    forall(&gens::usize_in(2..=40), 64, |&n| {
        let rounds = reduce_scatter_ring_rounds(n, s, elems);
        if rounds.len() != n - 1 {
            return false;
        }
        let shard = s / n as f64;
        let fold = elems / n as f64;
        let mut folded = vec![0.0f64; n];
        for round in &rounds {
            if round.len() != n {
                return false;
            }
            for op in round {
                if op.dst != (op.src + 1) % n || op.bytes != shard || op.reduce_elems != fold {
                    return false;
                }
                folded[op.dst] += op.reduce_elems;
            }
        }
        let want_rank = (n as f64 - 1.0) * fold;
        let want_total = (n as f64 - 1.0) * elems;
        let total: f64 = folded.iter().sum();
        (total - want_total).abs() <= want_total * 1e-12
            && folded.iter().all(|&f| (f - want_rank).abs() <= want_rank * 1e-12)
    });
}

#[test]
fn prop_all_to_all_exchanges_every_ordered_pair_exactly_once() {
    // n−1 rounds, each a perfect permutation (every rank sends once and
    // receives once), covering each ordered pair (i, j ≠ i) exactly once
    // with its private S/n block — conservation by construction
    let s = 4096.0;
    forall(&gens::usize_in(2..=40), 64, |&n| {
        let rounds = all_to_all_rounds(n, s);
        if rounds.len() != n - 1 {
            return false;
        }
        let block = s / n as f64;
        let mut pair = vec![vec![0usize; n]; n];
        for round in &rounds {
            let mut sent = vec![0usize; n];
            let mut recv = vec![0usize; n];
            for op in round {
                if op.src == op.dst || op.bytes != block || op.reduce_elems != 0.0 {
                    return false;
                }
                sent[op.src] += 1;
                recv[op.dst] += 1;
                pair[op.src][op.dst] += 1;
            }
            if sent.iter().any(|&c| c != 1) || recv.iter().any(|&c| c != 1) {
                return false;
            }
        }
        (0..n).all(|i| (0..n).all(|j| pair[i][j] == usize::from(i != j)))
    });
}

#[test]
fn prop_candidate_plans_conserve_movement_per_kind() {
    // every plan the kind-aware planner prices — across random shapes,
    // placements and message sizes, with and without switch engines —
    // delivers exactly the kind's required byte volume, folds exactly
    // its required element count (zero for the movement kinds), and a
    // switch-multicast phase covers every member exactly once
    forall(
        &gens::pair(
            gens::pair(gens::usize_in(1..=4), gens::usize_in(2..=5)),
            gens::pair(gens::usize_in(0..=2), gens::usize_in(1_000..=4_000_000)),
        ),
        24,
        |&((leaves, m), (oversub_idx, elems))| {
            let oversub = [1.0, 2.0, 4.0][oversub_idx];
            for sys in [SystemParams::smartnic_40g(), netreduce_sys(m.max(leaves))] {
                for (topo, ranks) in shapes(leaves, m, oversub) {
                    let n = ranks.len();
                    let raw = elems as f64 * 4.0;
                    let padded = elems.div_ceil(n).max(1) as f64 * 4.0 * n as f64;
                    for kind in KINDS {
                        let cands = planner::candidates_for(&sys, &topo, &ranks, elems, 1.0, kind);
                        // the host/NIC rounds plan is always present and
                        // always first (the fallback target)
                        let host_kind = match kind {
                            CollectiveKind::AllReduce => unreachable!(),
                            CollectiveKind::Broadcast => PlanKind::Binomial,
                            CollectiveKind::Allgather | CollectiveKind::ReduceScatter => {
                                PlanKind::Ring
                            }
                            CollectiveKind::AllToAll => PlanKind::Pairwise,
                        };
                        if cands.is_empty() || cands[0].kind != host_kind {
                            return false;
                        }
                        if !sys.switch.enabled() && cands.len() != 1 {
                            return false;
                        }
                        for cand in &cands {
                            if !cand.predicted.is_finite() || cand.predicted <= 0.0 {
                                return false;
                            }
                            // total bytes delivered to some rank's NIC
                            let mut delivered = 0.0;
                            for ph in &cand.phases {
                                match ph {
                                    Phase::Rounds(rounds) => {
                                        delivered +=
                                            rounds.iter().flatten().map(|op| op.bytes).sum::<f64>();
                                    }
                                    Phase::SwitchMulticast { bytes, groups } => {
                                        let mut seen = vec![0usize; n];
                                        for &local in groups.iter().flatten() {
                                            seen[local] += 1;
                                        }
                                        if seen.iter().any(|&c| c != 1) {
                                            return false;
                                        }
                                        delivered += (n as f64 - 1.0) * bytes;
                                    }
                                    Phase::SwitchReduce { .. } => return false,
                                }
                            }
                            let payload = match kind {
                                CollectiveKind::Broadcast => raw,
                                _ => padded,
                            };
                            let want = (n as f64 - 1.0) * payload;
                            if (delivered - want).abs() > want * 1e-9 {
                                return false;
                            }
                            // reduction ledger: only reduce-scatter folds
                            let want_folds = match kind {
                                CollectiveKind::ReduceScatter => (n as f64 - 1.0) * elems as f64,
                                _ => 0.0,
                            };
                            let folds = cand.reduced_elems(n, elems);
                            if (folds - want_folds).abs() > want_folds * 1e-9 + 1e-9 {
                                return false;
                            }
                        }
                    }
                }
            }
            true
        },
    );
}

#[test]
fn mcast_infinite_rate_converges_to_the_closed_form() {
    // replication-rate → ∞, table → ∞: the multicast segment pipeline's
    // only costs are DMA, serialization and latency — the closed form is
    // exact (the replication dual of planner.rs's in-switch convergence
    // guard), and sits just above the one-payload-through-the-root-Tx
    // wire bound
    let ideal = SystemParams::smartnic_40g().with_switch_reduction(SwitchParams {
        reduce_flops: f64::INFINITY,
        reduce_table_bytes: 1e18,
    });
    let hidden = 2048;
    let elems = hidden * hidden;
    for (topo, ranks, m, l, eff_oversub) in [
        (Topology::flat(8), (0..8).collect::<Vec<_>>(), 8usize, 1usize, 1.0),
        (Topology::leaf_spine(2, 4, 4.0), (0..8).collect::<Vec<_>>(), 4, 2, 4.0),
        // partial-leaf placement: 2 of 8 ranks per leaf, so the effective
        // tapering is m·oversub/nodes_per_leaf = 2·4/8 = 1.0
        (Topology::leaf_spine(2, 8, 4.0), vec![0, 1, 8, 9], 2, 2, 1.0),
    ] {
        let (measured, _) = measure_collective(
            ideal,
            topo,
            ranks,
            CollectiveKind::Broadcast,
            CollectiveAlgo::SwitchReduce,
            hidden,
            EngineKind::Typed,
        );
        let model = switch_multicast_time_elems(&ideal, elems, m, l, eff_oversub, 1.0);
        let err = rel_err(model, measured);
        assert!(
            err < 1e-9,
            "{}: engine {measured} vs closed form {model} ({err:.2e})",
            topo.describe()
        );
        let wire_bound = elems as f64 * 4.0 / ideal.net.effective_bw();
        assert!(measured > wire_bound, "beats the wire bound: {measured}");
        assert!(
            measured < wire_bound * 1.1,
            "not converged: {measured} vs bound {wire_bound}"
        );
    }
}

#[test]
fn multicast_incapable_switch_degrades_to_the_exact_binomial_tree() {
    // a switch with engines but a table that cannot hold one segment (or
    // no engines at all) must execute the *identical* host binomial-tree
    // broadcast — the replication mirror of the in-switch → ring
    // fallback guard
    let topo = Topology::leaf_spine(2, 3, 4.0);
    let ranks: Vec<usize> = (0..6).collect();
    for crippled in [
        SystemParams::smartnic_40g(), // no engines
        SystemParams::smartnic_40g().with_switch_reduction(SwitchParams {
            reduce_flops: 1e12,
            reduce_table_bytes: 0.0, // capacity 0: disabled outright
        }),
        SystemParams::smartnic_40g().with_switch_reduction(SwitchParams {
            reduce_flops: 1e12,
            reduce_table_bytes: 1024.0, // < one segment: planner must fall back
        }),
    ] {
        let (tree, _) = measure_collective(
            crippled,
            topo,
            ranks.clone(),
            CollectiveKind::Broadcast,
            CollectiveAlgo::NicBinomial,
            2048,
            EngineKind::Typed,
        );
        let (fallback, _) = measure_collective(
            crippled,
            topo,
            ranks.clone(),
            CollectiveKind::Broadcast,
            CollectiveAlgo::SwitchReduce,
            2048,
            EngineKind::Typed,
        );
        assert!(
            (tree - fallback).abs() <= tree * 1e-12,
            "fallback differs from the binomial tree: {fallback} vs {tree}"
        );
    }
}

#[test]
fn every_executed_kind_audits_clean_on_the_checked_engine() {
    // the executed half of the conservation property: the checked
    // engine's ledger (per-kind expected folds, multicast replication
    // copies, no leaked reservations, no unfinished collectives) matches
    // what the fabric actually did, for every kind on both fabric shapes
    let sys = netreduce_sys(8);
    let ls = Topology::leaf_spine(2, 3, 2.0);
    for kind in KINDS {
        for (topo, ranks) in [
            (Topology::flat(6), (0..6).collect::<Vec<_>>()),
            (ls, ls.contiguous_ranks(6)),
        ] {
            let (_, audit) = measure_collective(
                sys,
                topo,
                ranks,
                kind,
                CollectiveAlgo::Auto,
                256,
                EngineKind::Checked { threads: 0 },
            );
            let report = audit.expect("checked engine carries a report");
            assert!(
                report.is_clean(),
                "{}/{}: {}",
                kind.name(),
                topo.describe(),
                report.summary()
            );
        }
    }
    // force the multicast offload explicitly so the replication ledger
    // (not just the host paths) is exercised under audit
    let (_, audit) = measure_collective(
        sys,
        ls,
        ls.contiguous_ranks(6),
        CollectiveKind::Broadcast,
        CollectiveAlgo::SwitchReduce,
        256,
        EngineKind::Checked { threads: 0 },
    );
    let report = audit.expect("checked engine carries a report");
    assert!(report.is_clean(), "forced multicast: {}", report.summary());
}
