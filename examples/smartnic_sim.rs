//! Smart-NIC deep dive: chunk-level DES of a single in-network all-reduce
//! — per-resource utilization, wire accounting, and the T_ring / T_add /
//! T_mem regimes of Sec. IV-C made visible.

use ai_smartnic::analytic::validate::smartnic_ar_time_elems;
use ai_smartnic::bfp::BfpCodec;
use ai_smartnic::nic::{simulate_ring_allreduce, NicConfig};
use ai_smartnic::sysconfig::SystemParams;
use ai_smartnic::util::table::{fnum, Table};
use ai_smartnic::util::units::fmt_time;

fn main() {
    let sys = SystemParams::smartnic_40g();
    println!("one 2048x2048 FP32 gradient (16.8 MB) through the NIC ring:\n");
    let mut t = Table::new(&[
        "nodes", "wire", "t_sim", "t_model", "err", "eth util", "pcie util", "adder util",
    ]);
    for bfp in [false, true] {
        for n in [2usize, 3, 4, 6, 8, 16, 32] {
            let cfg = NicConfig::new(sys, if bfp { Some(BfpCodec::bfp16()) } else { None });
            let r = simulate_ring_allreduce(&cfg, n, 2048 * 2048);
            let model = smartnic_ar_time_elems(&sys, 2048 * 2048, n, bfp);
            t.row(&[
                format!("{n}{}", if bfp { " +BFP" } else { "" }),
                format!("{:.1} MB", r.wire_bytes_per_node / 1e6),
                fmt_time(r.t_total),
                fmt_time(model),
                format!("{:.1}%", 100.0 * (model - r.t_total).abs() / r.t_total),
                fnum(r.eth_util, 2),
                fnum(r.pcie_util, 2),
                fnum(r.adder_util, 2),
            ]);
        }
    }
    t.print();
    println!(
        "\nregimes: raw FP32 is Ethernet-bound (T_ring); with BFP16 the wire empties \
         and PCIe (T_mem) takes over — exactly the max() structure of Sec. IV-C."
    );

    // message-size sweep: latency floor to bandwidth asymptote
    println!("\nmessage-size sweep at 6 nodes (+BFP):\n");
    let mut t = Table::new(&["elements", "t_sim", "effective GB/s/node"]);
    let cfg = NicConfig::new(sys, Some(BfpCodec::bfp16()));
    for log2 in [10usize, 14, 18, 22, 24] {
        let elems = 1usize << log2;
        let r = simulate_ring_allreduce(&cfg, 6, elems);
        t.row(&[
            format!("2^{log2}"),
            fmt_time(r.t_total),
            fnum(elems as f64 * 4.0 / r.t_total / 1e9, 2),
        ]);
    }
    t.print();
}
