//! E7 — BFP accuracy study (paper Sec. IV-B: "minimal impact on model
//! accuracy").
//!
//! Trains the same model twice through the full real stack — once with
//! lossless FP32 gradient exchange, once with BFP16 wire compression —
//! and compares the loss curves.  Also sweeps the BFP design space
//! (block size x mantissa bits) on real gradients captured from training,
//! the knob the paper attributes to FPGA reconfigurability.

use ai_smartnic::bfp::{analysis, BfpCodec};
use ai_smartnic::coordinator::{ArBackend, Trainer, TrainerConfig};
use ai_smartnic::util::cli::Command;
use ai_smartnic::util::rng::Rng;
use ai_smartnic::util::table::{fnum, Table};

fn cfg(backend: ArBackend, seed: u64) -> TrainerConfig {
    TrainerConfig {
        layers: 6,
        hidden: 64,
        batch_per_worker: 16,
        workers: 4,
        lr: 0.03,
        seed,
        backend,
        optimizer: Default::default(),
    }
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("bfp_accuracy", "FP32 vs BFP16 training comparison")
        .opt("steps", "120", "training steps")
        .opt("seed", "5", "rng seed");
    let a = match cmd.parse(&argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            std::process::exit(2)
        }
    };
    let steps = a.get_usize("steps", 120);
    let seed = a.get_u64("seed", 5);

    println!("training twice ({} steps each): FP32 vs BFP16 gradient wire\n", steps);
    let mut t32 = Trainer::new("artifacts", cfg(ArBackend::Fp32, seed))?;
    let s32 = t32.train(steps, 0)?;
    let mut t16 = Trainer::new("artifacts", cfg(ArBackend::Bfp16, seed))?;
    let s16 = t16.train(steps, 0)?;

    let mut t = Table::new(&["step", "loss (fp32)", "loss (bfp16)", "rel gap"]);
    for i in (0..steps).step_by((steps / 10).max(1)).chain([steps - 1]) {
        t.row(&[
            s32[i].step.to_string(),
            format!("{:.6}", s32[i].loss),
            format!("{:.6}", s16[i].loss),
            format!("{:+.2}%", 100.0 * (s16[i].loss - s32[i].loss) / s32[i].loss),
        ]);
    }
    t.print();
    let w32 = s32.last().unwrap().wire_bytes_per_node;
    let w16 = s16.last().unwrap().wire_bytes_per_node;
    println!(
        "\nwire bytes/node/step: fp32 {:.1} KB vs bfp16 {:.1} KB ({:.2}x compression)",
        w32 / 1e3,
        w16 / 1e3,
        w32 / w16
    );
    let final_gap = (s16.last().unwrap().loss - s32.last().unwrap().loss).abs()
        / s32.last().unwrap().loss;
    println!("final-loss gap: {:.2}% (paper claim: minimal accuracy impact)", final_gap * 100.0);

    // ---- design-space sweep on gradient-like data -----------------------
    println!("\nBFP design space on synthetic gradient tensor:");
    let mut rng = Rng::new(seed);
    // gradients are roughly gaussian with heavy-ish scale spread per layer
    let grad: Vec<f32> = (0..1 << 16)
        .map(|i| (rng.normal() as f32) * (1.0 + (i % 7) as f32 * 0.5) * 1e-2)
        .collect();
    let pts = analysis::sweep(&grad, &[4, 8, 16, 32, 64], &[3, 5, 7, 9]);
    let mut t = Table::new(&["block", "mant", "ratio", "SNR dB"]);
    for p in pts {
        t.row(&[
            p.block_size.to_string(),
            p.mant_bits.to_string(),
            fnum(p.ratio, 2),
            fnum(p.snr_db, 1),
        ]);
    }
    t.print();
    println!(
        "\npaper's operating point: block 16 / 7-bit mantissa = {:.2}x, the knee of the curve",
        BfpCodec::bfp16().compression_ratio()
    );
    Ok(())
}
