//! Scaling study — regenerates the paper's Fig. 4b narrative end to end:
//! DES "measurements" on prototype sizes (<=6 nodes), analytical model
//! beyond, both batch sizes, plus the smart-NIC bandwidth ablation
//! (40 -> 100 -> 400 Gbps NICs, Sec. V-A's forward-looking variants).

use ai_smartnic::analytic::model::{iteration, SystemKind};
use ai_smartnic::experiments::fig4b;
use ai_smartnic::sysconfig::{SystemParams, Workload};
use ai_smartnic::util::table::{fnum, Table};

fn main() {
    let nodes = [1usize, 2, 3, 4, 5, 6, 8, 12, 16, 24, 32];
    for batch in [448usize, 1792] {
        let series = fig4b::run(&nodes, batch);
        fig4b::print(&series, batch);
    }

    // ---- NIC line-rate ablation (beyond the paper's prototype) --------
    println!("smart-NIC line-rate ablation (B=448, model, normalized to 1 node):\n");
    let w = Workload::paper_mlp(448);
    let t1 = iteration(
        SystemKind::SmartNic { bfp: false },
        &SystemParams::smartnic_40g(),
        &w,
        1,
    )
    .t_total;
    let mut t = Table::new(&["NIC speed", "6n", "16n", "32n", "32n w/ BFP"]);
    for gbps in [40.0, 100.0, 400.0] {
        let sys = SystemParams::smartnic_at(gbps);
        let norm = |n: usize, bfp: bool| {
            n as f64 * t1 / iteration(SystemKind::SmartNic { bfp }, &sys, &w, n).t_total
        };
        t.row(&[
            format!("{gbps:.0} Gbps"),
            fnum(norm(6, false), 1),
            fnum(norm(16, false), 1),
            fnum(norm(32, false), 1),
            fnum(norm(32, true), 1),
        ]);
    }
    t.print();
    println!("\nat 100+ Gbps the ring stops being the bottleneck; BFP's benefit shifts entirely to PCIe relief");
}
