//! Quickstart: the 60-second tour.
//!
//! 1. simulate one training iteration on the paper's three systems,
//! 2. quantize a gradient through the BFP16 wire codec,
//! 3. run a few real training steps through the PJRT artifacts.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use ai_smartnic::analytic::model::SystemKind;
use ai_smartnic::bfp::BfpCodec;
use ai_smartnic::collective::Scheme;
use ai_smartnic::coordinator::{simulate_iteration, ArBackend, Trainer, TrainerConfig};
use ai_smartnic::sysconfig::{SystemParams, Workload};
use ai_smartnic::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // --- 1. simulate the paper's headline comparison --------------------
    println!("## simulated iteration time, 20-layer 2048^2 MLP, B=448, 6 nodes\n");
    let w = Workload::paper_mlp(448);
    for (name, kind, sys) in [
        (
            "baseline (overlapped host AR)",
            SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
            SystemParams::baseline_100g(),
        ),
        ("AI smart NIC", SystemKind::SmartNic { bfp: false }, SystemParams::smartnic_40g()),
        ("AI smart NIC + BFP16", SystemKind::SmartNic { bfp: true }, SystemParams::smartnic_40g()),
    ] {
        let bd = simulate_iteration(kind, &sys, &w, 6).breakdown;
        println!(
            "  {name:32} {:7.1} ms/iter  (exposed all-reduce {:5.1} ms)",
            bd.t_total * 1e3,
            bd.t_exposed_ar * 1e3
        );
    }

    // --- 2. the BFP16 wire codec ----------------------------------------
    println!("\n## BFP16 gradient compression (block 16, 7-bit mantissa)\n");
    let codec = BfpCodec::bfp16();
    let mut rng = Rng::new(0);
    let grad: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
    let q = codec.quantize(&grad);
    let err: f64 = grad
        .iter()
        .zip(&q)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        .sqrt()
        / grad.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    println!(
        "  compression {:.2}x, relative L2 error {:.4} ({} -> {} bytes)",
        codec.compression_ratio(),
        err,
        grad.len() * 4,
        codec.wire_bytes(grad.len())
    );

    // --- 3. real training through PJRT ----------------------------------
    println!("\n## real training: 3-layer 64-wide MLP, 3 workers, BFP16 wire\n");
    let cfg = TrainerConfig {
        layers: 3,
        hidden: 64,
        batch_per_worker: 16,
        workers: 3,
        lr: 0.04,
        seed: 1,
        backend: ArBackend::Bfp16,
        optimizer: Default::default(),
    };
    match Trainer::new("artifacts", cfg) {
        Ok(mut t) => {
            let stats = t.train(20, 0)?;
            println!(
                "  loss {:.4} -> {:.4} over {} steps (wire {:.1} KB/node/step)",
                stats[0].loss,
                stats.last().unwrap().loss,
                stats.len(),
                stats[0].wire_bytes_per_node / 1e3
            );
        }
        Err(e) => println!("  (skipped — run `make artifacts` first: {e})"),
    }
    Ok(())
}
