//! E8 — the end-to-end training driver (the repo's full-stack proof).
//!
//! Trains the paper's workload shape — a deep symmetric MLP — across
//! data-parallel workers with every layer of this repo in the loop:
//!
//!   L1  Pallas kernels (matmul / BFP / adder) inside the AOT artifacts
//!   L2  the layerwise JAX model, AOT-lowered to HLO text
//!   L3  this Rust coordinator: PJRT execution + real ring all-reduce
//!       with real BFP16 wire quantization, per the Fig. 3b schedule
//!
//! The paper's full-size experiment is a 20-layer 2048^2 MLP (83.9M
//! params); on this 1-core CPU testbed the default is the same *depth*
//! at reduced width (8 x 256^2, via the standard artifact set) for a few
//! hundred steps, logging the loss curve.  `--paper-scale` runs the real
//! 2048-wide, 448-batch shape for a few steps (requires
//! `make artifacts-full`) and reports per-phase times used to calibrate
//! the simulator's compute model.
//!
//! Run: `cargo run --release --example train_e2e -- [--steps N]
//!       [--workers N] [--backend fp32|bfp16] [--paper-scale]`

use ai_smartnic::coordinator::{ArBackend, Trainer, TrainerConfig};
use ai_smartnic::util::cli::Command;
use ai_smartnic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("train_e2e", "end-to-end training driver")
        .opt("steps", "300", "training steps")
        .opt("workers", "6", "data-parallel workers (paper prototype: 6)")
        .opt("layers", "8", "MLP depth")
        .opt("backend", "bfp16", "gradient wire format: fp32 | bfp16")
        .opt("lr", "0.03", "learning rate")
        .opt("seed", "17", "rng seed")
        .opt("out", "results/train_e2e.json", "loss-curve output")
        .flag("paper-scale", "20-layer 2048^2, B=448 (needs artifacts-full)");
    let a = match cmd.parse(&argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };

    let paper = a.flag("paper-scale");
    let cfg = TrainerConfig {
        layers: if paper { 20 } else { a.get_usize("layers", 8) },
        hidden: if paper { 2048 } else { 256 },
        batch_per_worker: if paper { 448 } else { 32 },
        workers: a.get_usize("workers", 6),
        lr: a.get_f64("lr", 0.03) as f32,
        seed: a.get_u64("seed", 17),
        backend: match a.get_str("backend", "bfp16").as_str() {
            "fp32" => ArBackend::Fp32,
            _ => ArBackend::Bfp16,
        },
        optimizer: Default::default(),
    };
    let steps = if paper { 3.min(a.get_usize("steps", 3)) } else { a.get_usize("steps", 300) };
    let params = cfg.layers * cfg.hidden * cfg.hidden;
    println!(
        "e2e training: {}-layer {}^2 MLP ({:.1}M params), {} workers, B={}/worker, {:?} wire",
        cfg.layers,
        cfg.hidden,
        params as f64 / 1e6,
        cfg.workers,
        cfg.batch_per_worker,
        cfg.backend
    );

    let mut trainer = Trainer::new("artifacts", cfg.clone())?;
    let t0 = std::time::Instant::now();
    let stats = trainer.train(steps, if paper { 1 } else { 25 })?;
    let wall = t0.elapsed().as_secs_f64();

    let first = &stats[0];
    let last = stats.last().unwrap();
    println!("\nloss curve: {:.5} -> {:.5} over {} steps", first.loss, last.loss, stats.len());
    println!(
        "wall time {wall:.1}s ({:.2} s/step); per-phase means: fwd {:.0} ms, bwd {:.0} ms, allreduce {:.0} ms, update {:.0} ms",
        wall / stats.len() as f64,
        1e3 * stats.iter().map(|s| s.t_fwd).sum::<f64>() / stats.len() as f64,
        1e3 * stats.iter().map(|s| s.t_bwd).sum::<f64>() / stats.len() as f64,
        1e3 * stats.iter().map(|s| s.t_allreduce).sum::<f64>() / stats.len() as f64,
        1e3 * stats.iter().map(|s| s.t_update).sum::<f64>() / stats.len() as f64,
    );
    println!(
        "wire traffic: {:.2} MB/node/step (gradient volume {:.2} MB raw)",
        last.wire_bytes_per_node / 1e6,
        params as f64 * 4.0 / 1e6
    );

    // dump the loss curve
    let curve = Json::Arr(
        stats
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("step", Json::Num(s.step as f64)),
                    ("loss", Json::Num(s.loss)),
                ])
            })
            .collect(),
    );
    let out = a.get_str("out", "results/train_e2e.json");
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, curve.to_string_pretty())?;
    println!("loss curve written to {out}");

    // per-artifact execution profile (the PJRT hot path)
    println!("\nPJRT execution profile:");
    for (name, s) in trainer.engine().stats().iter().take(8) {
        println!(
            "  {:32} {:>8} calls  {:>10.3} ms total  {:>8.3} ms/call",
            name,
            s.calls,
            s.total_secs * 1e3,
            s.total_secs * 1e3 / s.calls as f64
        );
    }
    Ok(())
}
