//! Topology-aware planner walkthrough: the same 32-node all-reduce on a
//! 4:1-oversubscribed leaf–spine fabric under every offload the repo
//! models — the flat NIC ring, the planner's hierarchical plan
//! (reduce-scatter in leaf → shard ring across the spine → allgather),
//! NetReduce-style in-switch reduction, and `Auto` (the planner's own
//! pick) — for both placements.
//!
//! Run with: `cargo run --release --example planner_study`

use ai_smartnic::cluster::planner::plan;
use ai_smartnic::cluster::{CollectiveAlgo, Topology};
use ai_smartnic::experiments::planner::measure_ar;
use ai_smartnic::sysconfig::{SwitchParams, SystemParams};
use ai_smartnic::util::table::{fnum, Table};

fn main() {
    let base = SystemParams::smartnic_40g();
    let sys = base.with_switch_reduction(SwitchParams::netreduce(8, &base.net));
    let n = 32;
    let hidden = 2048;
    let topo = Topology::leaf_spine(4, n / 4, 4.0);

    let measure =
        |ranks: Vec<usize>, algo: CollectiveAlgo| measure_ar(sys, topo, ranks, algo, hidden);

    let mut t = Table::new(&["placement", "algorithm", "AR (ms)", "vs ring"]).with_title(
        "one 16.8 MB all-reduce, 32 nodes on a 4x8 leaf-spine, 4:1 oversubscribed",
    );
    for (placement, ranks) in [
        ("contiguous", topo.contiguous_ranks(n)),
        ("strided", topo.strided_ranks(n)),
    ] {
        let ring = measure(ranks.clone(), CollectiveAlgo::NicRing);
        let chosen = plan(&sys, &topo, &ranks, hidden * hidden, 1.0);
        for (name, algo) in [
            ("nic-ring", CollectiveAlgo::NicRing),
            ("hierarchical", CollectiveAlgo::NicHierarchical),
            ("in-switch", CollectiveAlgo::SwitchReduce),
            ("auto", CollectiveAlgo::Auto),
        ] {
            let ar = measure(ranks.clone(), algo);
            let label = if name == "auto" {
                format!("auto -> {}", chosen.kind.name())
            } else {
                name.to_string()
            };
            t.row(&[
                placement.to_string(),
                label,
                fnum(ar * 1e3, 2),
                format!("x{}", fnum(ring / ar, 2)),
            ]);
        }
    }
    t.print();

    println!(
        "\nstrided placement makes every ring edge cross the tapered spine (~4x penalty);\n\
         the hierarchical plan crosses it with 1/m-th of the traffic and recovers most of\n\
         that, and line-rate switch engines beat the NIC ring everywhere — until the\n\
         aggregation table cannot hold a segment, where the planner falls back to the NIC\n\
         ring.  `smartnic plan` sweeps 6..512 nodes and writes BENCH_planner.json."
    );
}
