//! Leaf–spine fabric walkthrough: the same 32-node training job under
//! every combination of placement and oversubscription, showing where the
//! ring all-reduce's contention-freedom (paper Sec. II-B) survives the
//! jump from one crossbar to a tapered multi-switch fabric — and where it
//! breaks.
//!
//! Run with: `cargo run --release --example leaf_spine_cluster`

use ai_smartnic::analytic::model::SystemKind;
use ai_smartnic::cluster::{run_scenario, ClusterSpec, JobSpec, Topology};
use ai_smartnic::sysconfig::{SystemParams, Workload};
use ai_smartnic::util::table::{fnum, Table};

fn main() {
    let sys = SystemParams::smartnic_40g();
    let w = Workload::paper_mlp(448);
    let kind = SystemKind::SmartNic { bfp: false };
    let n = 32;
    let leaves = 4;

    let run = |topology: Topology, ranks: Vec<usize>| {
        let out = run_scenario(
            &ClusterSpec::new(sys, n)
                .with_topology(topology)
                .with_job(JobSpec::new("job", kind, w, ranks)),
        );
        let j = &out.jobs[0];
        (j.duration, j.mean_ar, j.exposed_wait)
    };

    let flat = run(Topology::flat(n), (0..n).collect());

    let mut t = Table::new(&[
        "fabric",
        "placement",
        "iteration (ms)",
        "mean AR (ms)",
        "exposed wait (ms)",
        "vs flat",
    ])
    .with_title("32-node smart-NIC job across fabric shapes (B=448/node)");
    t.row(&[
        "flat crossbar".to_string(),
        "-".to_string(),
        fnum(flat.0 * 1e3, 1),
        fnum(flat.1 * 1e3, 2),
        fnum(flat.2 * 1e3, 1),
        "x1.00".to_string(),
    ]);
    for oversub in [1.0, 4.0] {
        let topo = Topology::leaf_spine(leaves, n / leaves, oversub);
        for (placement, ranks) in [
            ("contiguous", topo.contiguous_ranks(n)),
            ("strided", topo.strided_ranks(n)),
        ] {
            let r = run(topo, ranks);
            t.row(&[
                format!("leaf-spine {oversub}:1"),
                placement.to_string(),
                fnum(r.0 * 1e3, 1),
                fnum(r.1 * 1e3, 2),
                fnum(r.2 * 1e3, 1),
                format!("x{}", fnum(r.0 / flat.0, 2)),
            ]);
        }
    }
    t.print();

    println!(
        "\ncontiguous placement keeps ring edges inside the leaves (one spine crossing per\n\
         leaf boundary), so even a 4:1 tapered spine costs almost nothing; strided placement\n\
         pushes every edge across the uplinks and the ring queues by ~the tapering factor.\n\
         `smartnic scale` sweeps this to 512 nodes and writes BENCH_scaling.json."
    );
}
