//! The unified cluster engine in action — the three behaviors the
//! serialized one-ring-at-a-time simulator cannot express:
//!
//! 1. **true layerwise overlap**: ≥2 of one job's all-reduces in flight
//!    at once, visible in the trace;
//! 2. **multi-tenant contention**: two training jobs sharing one switch
//!    fabric slow each other down;
//! 3. **cluster-wide fault injection**: one straggler node degrades every
//!    in-flight collective of every job.

use ai_smartnic::analytic::model::SystemKind;
use ai_smartnic::cluster::{run_scenario, ClusterSpec, JobSpec};
use ai_smartnic::sysconfig::{ClusterFaults, SystemParams, Workload};
use ai_smartnic::util::table::{fnum, Table};

fn main() {
    let sys = SystemParams::smartnic_40g();
    let w = Workload::paper_mlp(448);
    let kind = SystemKind::SmartNic { bfp: false };
    let nodes = 6usize;

    // --- 1. concurrent all-reduces within one job ---------------------
    let solo = run_scenario(
        &ClusterSpec::new(sys, nodes)
            .with_job(JobSpec::new("solo", kind, w, (0..nodes).collect())),
    );
    let j = &solo.jobs[0];
    println!("single job, B=448 raw FP32 on {nodes} nodes:");
    println!(
        "  iteration {} ms, mean AR {} ms, max {} all-reduces in flight \
         (trace sees {} overlapping 'ar' spans)",
        fnum(j.duration * 1e3, 2),
        fnum(j.mean_ar * 1e3, 2),
        j.max_inflight,
        solo.trace.max_concurrent("ar"),
    );
    assert!(
        solo.trace.max_concurrent("ar") >= 2,
        "expected overlapping all-reduces in the trace"
    );

    // --- 2. two jobs on one fabric -------------------------------------
    let pair = run_scenario(
        &ClusterSpec::new(sys, nodes)
            .with_job(JobSpec::new("j0", kind, w, (0..nodes).collect()))
            .with_job(JobSpec::new("j1", kind, w, (0..nodes).collect())),
    );
    println!("\ntwo identical jobs sharing all {nodes} nodes:");
    let mut t = Table::new(&["job", "duration (ms)", "slowdown vs solo", "exposed wait (ms)"]);
    for jr in &pair.jobs {
        t.row(&[
            jr.name.clone(),
            fnum(jr.duration * 1e3, 2),
            format!("x{}", fnum(jr.duration / j.duration, 2)),
            fnum(jr.exposed_wait * 1e3, 2),
        ]);
    }
    t.print();
    println!(
        "  fabric under contention: eth util {:.2} (solo was {:.2})",
        pair.eth_util, solo.eth_util
    );

    // --- 3. one straggler hurts everyone -------------------------------
    let faulty = run_scenario(
        &ClusterSpec::new(sys, nodes)
            .with_faults(ClusterFaults::none().with_straggler(2, 0.25))
            .with_job(JobSpec::new("j0", kind, w, (0..nodes).collect()))
            .with_job(JobSpec::new("j1", kind, w, (0..nodes).collect())),
    );
    println!("\nsame two jobs with node 2 throttled to 25% (PCIe + adder):");
    for (jr, healthy) in faulty.jobs.iter().zip(&pair.jobs) {
        println!(
            "  {}: {} ms (was {} ms) -> x{} slower",
            jr.name,
            fnum(jr.duration * 1e3, 2),
            fnum(healthy.duration * 1e3, 2),
            fnum(jr.duration / healthy.duration, 2)
        );
    }

    println!("\nGantt of the two-job run (F fwd, B bwd, U upd, A all-reduce, . wait):\n");
    println!("{}", pair.trace.render_gantt(96));
}
